//! Forward greedy sparse PCA baseline (Moghaddam et al. [5], d'Aspremont
//! et al. [6]) — the strongest of the "local" methods the DSPCA line of
//! work compares against; included for the ablation benches.
//!
//! Grows the support one feature at a time, at each step adding the
//! feature that maximizes the leading eigenvalue of the principal
//! submatrix. O(k · n · T_eig(k)) total — tractable for the small target
//! cardinalities the paper cares about, but with no optimality guarantee
//! (problem (2) is NP-hard; greedy can get stuck, see the tests).

use crate::data::SymMat;
use crate::linalg::eig::JacobiEig;
use crate::solver::extract::SparsePc;

/// Result of a greedy run: the chosen support at every prefix size, so one
/// run yields the whole cardinality path.
#[derive(Clone, Debug)]
pub struct GreedyPath {
    /// `path[k]` = (support of size k+1, its λ_max).
    pub path: Vec<(Vec<usize>, f64)>,
}

impl GreedyPath {
    /// The sparse PC at cardinality `k` (1-based; clamped to the path).
    pub fn pc_at(&self, sigma: &SymMat, k: usize) -> SparsePc {
        let idx = k.clamp(1, self.path.len()) - 1;
        let (support, _) = &self.path[idx];
        let sub = sigma.submatrix(support);
        let eig = JacobiEig::new(&sub);
        let mut vector = vec![0.0; sigma.n()];
        for (pos, &orig) in support.iter().enumerate() {
            vector[orig] = eig.vector(0)[pos];
        }
        // canonical sign + sorted support (largest |loading| first)
        let mut sup: Vec<usize> = support.clone();
        sup.sort_by(|&a, &b| vector[b].abs().partial_cmp(&vector[a].abs()).unwrap());
        if let Some(&lead) = sup.first() {
            if vector[lead] < 0.0 {
                for x in vector.iter_mut() {
                    *x = -*x;
                }
            }
        }
        SparsePc { vector, support: sup, z_eigenvalue: f64::NAN }
    }
}

/// Run forward greedy selection up to cardinality `max_card`.
pub fn forward(sigma: &SymMat, max_card: usize) -> GreedyPath {
    let n = sigma.n();
    let max_card = max_card.min(n);
    let mut support: Vec<usize> = Vec::new();
    let mut in_support = vec![false; n];
    let mut path = Vec::with_capacity(max_card);
    for _ in 0..max_card {
        let mut best: Option<(usize, f64)> = None;
        for cand in 0..n {
            if in_support[cand] {
                continue;
            }
            support.push(cand);
            let lam = JacobiEig::new(&sigma.submatrix(&support)).lambda_max();
            support.pop();
            if best.map_or(true, |(_, b)| lam > b) {
                best = Some((cand, lam));
            }
        }
        let (chosen, lam) = best.expect("candidates remain");
        support.push(chosen);
        in_support[chosen] = true;
        path.push((support.clone(), lam));
    }
    GreedyPath { path }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::models::spiked_covariance_with_u;
    use crate::util::check::{ensure, property};
    use crate::util::rng::Rng;

    #[test]
    fn first_pick_is_max_variance() {
        let sigma = SymMat::from_fn(4, |i, j| if i == j { [1.0, 3.0, 2.0, 0.5][i] } else { 0.0 });
        let g = forward(&sigma, 2);
        assert_eq!(g.path[0].0, vec![1]);
        assert!((g.path[0].1 - 3.0).abs() < 1e-10);
    }

    #[test]
    fn prop_path_monotone_and_nested() {
        property("greedy path: λmax non-decreasing, supports nested", 10, |rng| {
            let n = rng.range(3, 12);
            let sigma = SymMat::random_psd(n, n + 4, 0.05, rng);
            let g = forward(&sigma, n.min(6));
            for w in g.path.windows(2) {
                ensure(w[1].1 >= w[0].1 - 1e-10, "λmax must not decrease")?;
                ensure(
                    w[0].0.iter().all(|i| w[1].0.contains(i)),
                    "supports must be nested",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn recovers_strong_spike() {
        let mut rng = Rng::seed_from(171);
        let (sigma, u) = spiked_covariance_with_u(25, 100, 4, 6.0, &mut rng);
        let g = forward(&sigma, 4);
        let planted = crate::linalg::vec::support(&u, 1e-9);
        let hits = g.path[3].0.iter().filter(|i| planted.contains(i)).count();
        assert!(hits >= 3, "greedy found {:?}, planted {planted:?}", g.path[3].0);
        // and the extracted PC is unit-norm on that support
        let pc = g.pc_at(&sigma, 4);
        assert!((crate::linalg::vec::norm2(&pc.vector) - 1.0).abs() < 1e-9);
        assert_eq!(pc.cardinality(), 4);
    }

    #[test]
    fn greedy_never_beats_dspca_bound() {
        // φ (SDP) upper-bounds ψ = λmax(submatrix) − λ·k for every support,
        // including greedy's — the relaxation sandwich of §2.
        let mut rng = Rng::seed_from(172);
        let (sigma, _) = spiked_covariance_with_u(18, 60, 3, 4.0, &mut rng);
        let g = forward(&sigma, 5);
        let d: Vec<f64> = (0..18).map(|i| sigma.get(i, i)).collect();
        let lambda = crate::elim::lambda_for_survivors(&d, 9);
        let sol = crate::solver::bca::solve(
            &sigma,
            lambda,
            &crate::solver::bca::BcaOptions { max_sweeps: 40, ..Default::default() },
        );
        for (support, lam_max) in &g.path {
            let psi = lam_max - lambda * support.len() as f64;
            assert!(
                sol.phi >= psi - 1e-5 * (1.0 + psi.abs()),
                "relaxation violated: φ={} < ψ(greedy k={})={psi}",
                sol.phi,
                support.len()
            );
        }
    }
}
