//! The box-constrained quadratic program of Algorithm 1, step 4:
//!
//! ```text
//! R² := min_u  uᵀ Y u   s.t.  ‖u − s‖∞ ≤ λ            (11)
//! ```
//!
//! solved by cyclic coordinate descent with the closed-form scalar update
//! (13). `Y ⪰ 0` makes the problem convex; coordinate descent over a box
//! converges to the global optimum.
//!
//! This is **the paper's compute hot-spot**: one QP per row/column update,
//! n updates per sweep. The implementation below is the optimized native
//! (L3) version; the same algorithm is also implemented as the Pallas L1
//! kernel (`python/compile/kernels/boxqp.py`), and the two are
//! cross-checked in the engine tests.
//!
//! Hot-path design (see EXPERIMENTS.md §Perf):
//! - maintains `w = Y·u` incrementally: a coordinate change `δ` costs one
//!   row-axpy `w += δ·Y[i,:]` instead of a fresh O(n²) matvec;
//! - generalized per-coordinate radii `r[i]` (the masked full-size
//!   formulation the XLA engine uses pins coordinate j with `r[j] = 0`);
//! - early exit when a full sweep moves no coordinate by more than `tol`.
//!
//! The solvers are generic over [`DenseRows`] — the QP's matrix is the
//! solver *iterate* `X` (not Σ), and its inner loop needs contiguous row
//! access once per coordinate update. For [`crate::data::SymMat`] the
//! generic code monomorphizes to exactly the pre-operator-layer
//! implementation, so results are bitwise unchanged.

use crate::covop::DenseRows;
use crate::linalg::vec::dot;

/// Options for the coordinate-descent QP solver.
#[derive(Clone, Copy, Debug)]
pub struct QpOptions {
    /// Maximum number of full sweeps.
    pub max_sweeps: usize,
    /// Early-exit tolerance on the largest coordinate move in a sweep.
    pub tol: f64,
}

impl Default for QpOptions {
    fn default() -> Self {
        QpOptions { max_sweeps: 100, tol: 1e-10 }
    }
}

/// Result of a QP solve.
#[derive(Clone, Debug)]
pub struct QpSolution {
    /// Optimal `u`.
    pub u: Vec<f64>,
    /// `R² = uᵀYu` at the solution (≥ 0 for PSD `Y`).
    pub r_squared: f64,
    /// Sweeps actually performed.
    pub sweeps: usize,
}

/// Scalar outcome of a buffer-based solve: the optimal `u` and `w = Y·u`
/// are left in the caller's buffers instead of being cloned — the BCA hot
/// loop calls this once per column and reads the buffers directly, so an
/// owned copy would be pure allocation overhead.
#[derive(Clone, Copy, Debug)]
pub struct QpOutcome {
    /// `R² = uᵀYu` at the solution (≥ 0 for PSD `Y`).
    pub r_squared: f64,
    /// Sweeps actually performed (full + active-set inner).
    pub sweeps: usize,
}

/// Closed-form scalar update (13): minimize `y₁η² + 2gη` over
/// `|η − s₁| ≤ r`, where `g = ŷᵀû` is the off-diagonal inner product.
#[inline]
pub fn coordinate_update(y1: f64, g: f64, s1: f64, r: f64) -> f64 {
    let (lo, hi) = (s1 - r, s1 + r);
    if y1 > 0.0 {
        let unconstrained = -g / y1;
        if unconstrained < lo {
            lo
        } else if unconstrained > hi {
            hi
        } else {
            unconstrained
        }
    } else {
        // y₁ = 0 (PSD ⇒ y₁ ≥ 0): objective is linear, pick the box edge.
        if g > 0.0 {
            lo
        } else {
            hi
        }
    }
}

/// Solve (11) over the *masked* full-size matrix: coordinates where
/// `radius[i] == 0` are pinned to `center[i]`; `skip` (if any) marks a
/// coordinate treated as excluded (`u[skip]` forced to 0 — the "row j
/// removed" of Algorithm 1 without copying the submatrix).
///
/// `y.row(i)` must be the full row; entries at `skip` are ignored because
/// `u[skip] = 0` never contributes to `w`.
pub fn solve_masked<Y: DenseRows + ?Sized>(
    y: &Y,
    center: &[f64],
    radius: &[f64],
    skip: Option<usize>,
    opts: QpOptions,
    u: &mut Vec<f64>,
    w: &mut Vec<f64>,
) -> QpSolution {
    let n = y.n();
    assert_eq!(center.len(), n);
    assert_eq!(radius.len(), n);
    // Initialize u at the box center (always feasible), honoring the skip.
    u.clear();
    u.extend_from_slice(center);
    if let Some(j) = skip {
        u[j] = 0.0;
    }
    // w = Y u (one full matvec; thereafter maintained incrementally).
    w.resize(n, 0.0);
    y.matvec(u, w);
    let mut sweeps = 0;
    for sweep in 0..opts.max_sweeps {
        sweeps = sweep + 1;
        let mut max_move = 0.0f64;
        for i in 0..n {
            if Some(i) == skip {
                continue;
            }
            let yi = y.row(i);
            let yii = yi[i];
            // g = Σ_{k≠i} Y[i,k] u[k] = w[i] − yii·u[i]
            let g = w[i] - yii * u[i];
            let new = if radius[i] == 0.0 {
                center[i]
            } else {
                coordinate_update(yii, g, center[i], radius[i])
            };
            let delta = new - u[i];
            if delta != 0.0 {
                u[i] = new;
                // w += delta * Y[:,i] (= row i by symmetry)
                crate::linalg::vec::axpy(delta, yi, w);
                max_move = max_move.max(delta.abs());
            }
        }
        if max_move <= opts.tol {
            break;
        }
    }
    if let Some(j) = skip {
        // u[j] stays 0; w entries are consistent by construction.
        debug_assert_eq!(u[j], 0.0);
    }
    let r_squared = dot(u, w).max(0.0);
    QpSolution { u: u.clone(), r_squared, sweeps }
}

/// Warm-started, active-set variant of [`solve_masked`] — the BCA hot
/// path (see EXPERIMENTS.md §Perf).
///
/// Differences from the cold reference solver:
/// - **Warm start**: `warm` (typically the column's solution from the
///   previous outer BCA sweep) seeds `u`, clamped into the current box;
///   `None` falls back to the box center exactly like [`solve_masked`].
/// - **Active-set sweeps**: after each full sweep, coordinates pinned at a
///   box edge are dropped from the iteration set, and inner sweeps touch
///   only the free coordinates — `O(|A|²)` instead of `O(n²)` per sweep —
///   with `w` maintained on the active set only. Before the next full
///   (verification) sweep, `w = Y·u` is recomputed in one fused blocked
///   matvec pass, so edge coordinates whose gradient sign flipped re-enter.
/// - Convergence is only declared by a *full* sweep moving nothing beyond
///   `tol`, so the fixed point is identical to the reference solver's (the
///   problem is convex; both satisfy the same KKT system — the property
///   tests pin φ and the KKT residual against [`solve_masked`]).
///
/// `active` is caller-provided scratch (persisted in the solver workspace
/// to avoid reallocation). On return `u` holds the solution and `w` holds
/// the exactly-consistent `Y·u` (the BCA write-back vector).
#[allow(clippy::too_many_arguments)]
pub fn solve_masked_warm<Y: DenseRows + ?Sized>(
    y: &Y,
    center: &[f64],
    radius: &[f64],
    skip: Option<usize>,
    opts: QpOptions,
    warm: Option<&[f64]>,
    u: &mut Vec<f64>,
    w: &mut Vec<f64>,
    active: &mut Vec<usize>,
) -> QpOutcome {
    let n = y.n();
    assert_eq!(center.len(), n);
    assert_eq!(radius.len(), n);
    // Seed u: warm start clamped into the current box, else the center.
    u.clear();
    match warm {
        Some(prev) => {
            assert_eq!(prev.len(), n);
            for i in 0..n {
                let v = prev[i].clamp(center[i] - radius[i], center[i] + radius[i]);
                u.push(v);
            }
        }
        None => u.extend_from_slice(center),
    }
    if let Some(j) = skip {
        u[j] = 0.0;
    }
    w.resize(n, 0.0);
    y.matvec(u, w);
    // Budgeting: `opts.max_sweeps` bounds *full* sweeps (so the warm path
    // never gets less full-sweep work than the reference on hard
    // instances); inner active-set sweeps are capped per round and cost
    // O(|A|²) each. `sweeps` reports the total executed.
    const INNER_CAP: usize = 8;
    let mut sweeps = 0;
    let mut full_sweeps = 0;
    while full_sweeps < opts.max_sweeps {
        // Full verification sweep: exact w maintenance over whole rows.
        full_sweeps += 1;
        sweeps += 1;
        let mut max_move = 0.0f64;
        for i in 0..n {
            if Some(i) == skip {
                continue;
            }
            let yi = y.row(i);
            let yii = yi[i];
            let g = w[i] - yii * u[i];
            let new = if radius[i] == 0.0 {
                center[i]
            } else {
                coordinate_update(yii, g, center[i], radius[i])
            };
            let delta = new - u[i];
            max_move = max_move.max(delta.abs());
            // Dead-band: sub-tol moves are already "converged" — applying
            // them would cost a full row-axpy each for no progress (the
            // reference path stops at the same granularity, via its
            // max_move check). Keeps u and w exactly consistent.
            if delta.abs() > opts.tol {
                u[i] = new;
                crate::linalg::vec::axpy(delta, yi, w);
            }
        }
        if max_move <= opts.tol {
            // Converged with w = Y·u exact — ready for r² and write-back.
            let r_squared = dot(u, w).max(0.0);
            return QpOutcome { r_squared, sweeps };
        }
        // Build the active set: free coordinates strictly inside the box.
        // Edge-pinned coordinates stay put during inner sweeps; the next
        // full sweep re-checks their gradients.
        active.clear();
        for i in 0..n {
            if Some(i) == skip || radius[i] == 0.0 {
                continue;
            }
            if (u[i] - center[i]).abs() < radius[i] {
                active.push(i);
            }
        }
        // Inner sweeps on the active set with w maintained on it only —
        // worthwhile only when the set is a strict minority (each inner
        // sweep then costs ≤ n²/4 versus n² for a full sweep).
        if !active.is_empty() && 2 * active.len() <= n {
            for _ in 0..INNER_CAP {
                sweeps += 1;
                let mut inner_move = 0.0f64;
                for &i in active.iter() {
                    let yi = y.row(i);
                    let yii = yi[i];
                    let g = w[i] - yii * u[i];
                    let new = coordinate_update(yii, g, center[i], radius[i]);
                    let delta = new - u[i];
                    inner_move = inner_move.max(delta.abs());
                    if delta.abs() > opts.tol {
                        u[i] = new;
                        // w += delta·Y[i, active] on the active set only.
                        crate::kernels::gather_axpy(delta, yi, active.as_slice(), w);
                    }
                }
                if inner_move <= opts.tol {
                    break;
                }
            }
            // w is stale outside the active set; refresh before verifying.
            y.matvec(u, w);
        }
    }
    let r_squared = dot(u, w).max(0.0);
    QpOutcome { r_squared, sweeps }
}

/// Convenience wrapper: solve (11) with uniform radius λ over an explicit
/// `Y` and `s` (allocates; the BCA hot loop uses [`solve_masked`] with
/// reused buffers instead).
pub fn solve<Y: DenseRows + ?Sized>(y: &Y, s: &[f64], lambda: f64, opts: QpOptions) -> QpSolution {
    let n = y.n();
    let radius = vec![lambda; n];
    let mut u = Vec::with_capacity(n);
    let mut w = Vec::with_capacity(n);
    solve_masked(y, s, &radius, None, opts, &mut u, &mut w)
}

/// KKT residual of a candidate solution (for tests): for each coordinate,
/// the gradient `2(Yu)_i` must vanish if `uᵢ` is interior, be ≥ 0 at the
/// lower edge, ≤ 0 at the upper edge. Returns the worst violation.
pub fn kkt_residual<Y: DenseRows + ?Sized>(y: &Y, s: &[f64], lambda: f64, u: &[f64]) -> f64 {
    let n = y.n();
    let mut w = vec![0.0; n];
    y.matvec(u, &mut w);
    let mut worst = 0.0f64;
    for i in 0..n {
        let grad = 2.0 * w[i];
        let (lo, hi) = (s[i] - lambda, s[i] + lambda);
        let edge_tol = 1e-9 * (1.0 + lambda.abs() + s[i].abs());
        let v = if u[i] <= lo + edge_tol {
            (-grad).max(0.0) // need grad ≥ 0 at lower edge
        } else if u[i] >= hi - edge_tol {
            grad.max(0.0) // need grad ≤ 0 at upper edge
        } else {
            grad.abs()
        };
        worst = worst.max(v);
        // feasibility
        worst = worst.max((lo - u[i]).max(0.0)).max((u[i] - hi).max(0.0));
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SymMat;
    use crate::util::check::{close, ensure, property};
    use crate::util::rng::Rng;

    #[test]
    fn coordinate_update_cases() {
        // interior optimum
        assert!((coordinate_update(2.0, -4.0, 0.0, 10.0) - 2.0).abs() < 1e-12);
        // clipped low / high
        assert_eq!(coordinate_update(1.0, 100.0, 0.0, 0.5), -0.5);
        assert_eq!(coordinate_update(1.0, -100.0, 0.0, 0.5), 0.5);
        // degenerate y1 = 0
        assert_eq!(coordinate_update(0.0, 1.0, 2.0, 0.5), 1.5);
        assert_eq!(coordinate_update(0.0, -1.0, 2.0, 0.5), 2.5);
        assert_eq!(coordinate_update(0.0, 0.0, 2.0, 0.5), 2.5);
    }

    #[test]
    fn identity_y_solution_is_projection_of_zero() {
        // Y = I: min ‖u‖² over box → u_i = clamp(0, s_i−λ, s_i+λ)
        let y = SymMat::identity(4);
        let s = [2.0, -0.3, 0.0, -5.0];
        let sol = solve(&y, &s, 0.5, QpOptions::default());
        assert!((sol.u[0] - 1.5).abs() < 1e-9);
        assert!((sol.u[1] - 0.0).abs() < 1e-9);
        assert!((sol.u[2] - 0.0).abs() < 1e-9);
        assert!((sol.u[3] + 4.5).abs() < 1e-9);
    }

    #[test]
    fn prop_kkt_and_feasible() {
        property("QP: feasible + KKT-optimal", 30, |rng| {
            let n = rng.range(1, 15);
            let y = SymMat::random_psd(n, n + 2, 0.01, rng);
            let s: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let lambda = rng.range_f64(0.05, 1.0);
            let sol = solve(&y, &s, lambda, QpOptions::default());
            for i in 0..n {
                ensure(
                    (sol.u[i] - s[i]).abs() <= lambda + 1e-9,
                    format!("infeasible at {i}"),
                )?;
            }
            let res = kkt_residual(&y, &s, lambda, &sol.u);
            ensure(res < 1e-6 * (1.0 + y.trace()), format!("KKT residual {res}"))?;
            ensure(sol.r_squared >= -1e-12, "R² must be ≥ 0")?;
            Ok(())
        });
    }

    #[test]
    fn prop_objective_below_feasible_points() {
        property("QP optimum ≤ random feasible points", 25, |rng| {
            let n = rng.range(1, 12);
            let y = SymMat::random_psd(n, n + 3, 0.05, rng);
            let s: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            let lambda = rng.range_f64(0.1, 1.0);
            let sol = solve(&y, &s, lambda, QpOptions::default());
            for _ in 0..20 {
                let cand: Vec<f64> = s
                    .iter()
                    .map(|&si| si + rng.range_f64(-lambda, lambda))
                    .collect();
                let obj = y.quad_form(&cand);
                ensure(
                    sol.r_squared <= obj + 1e-7 * (1.0 + obj.abs()),
                    format!("candidate beats optimum: {} < {}", obj, sol.r_squared),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn masked_skip_equals_submatrix_solve() {
        property("masked solve == explicit submatrix solve", 20, |rng| {
            let n = rng.range(2, 12);
            let y = SymMat::random_psd(n, n + 3, 0.05, rng);
            let j = rng.below(n);
            let lambda = rng.range_f64(0.1, 1.0);
            let s_full: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            // masked full-size solve
            let mut center = s_full.clone();
            center[j] = 0.0;
            let mut radius = vec![lambda; n];
            radius[j] = 0.0;
            let mut u = Vec::new();
            let mut w = Vec::new();
            let masked = solve_masked(
                &y,
                &center,
                &radius,
                Some(j),
                QpOptions::default(),
                &mut u,
                &mut w,
            );
            // explicit submatrix solve
            let keep: Vec<usize> = (0..n).filter(|&i| i != j).collect();
            let ysub = y.submatrix(&keep);
            let ssub: Vec<f64> = keep.iter().map(|&i| s_full[i]).collect();
            let sub = solve(&ysub, &ssub, lambda, QpOptions::default());
            close(masked.r_squared, sub.r_squared, 1e-6)?;
            Ok(())
        });
    }

    #[test]
    fn zero_radius_pins_all() {
        let mut rng = Rng::seed_from(81);
        let y = SymMat::random_psd(5, 8, 0.1, &mut rng);
        let s = rng.gauss_vec(5);
        let radius = vec![0.0; 5];
        let mut u = Vec::new();
        let mut w = Vec::new();
        let sol = solve_masked(&y, &s, &radius, None, QpOptions::default(), &mut u, &mut w);
        for i in 0..5 {
            assert_eq!(sol.u[i], s[i]);
        }
        assert!((sol.r_squared - y.quad_form(&s)).abs() < 1e-9);
    }

    #[test]
    fn early_exit_counts_sweeps() {
        let y = SymMat::identity(3);
        let sol = solve(&y, &[0.0, 0.0, 0.0], 1.0, QpOptions::default());
        // solution is u = 0 after the first sweep; second confirms.
        assert!(sol.sweeps <= 2);
        assert!(sol.r_squared < 1e-18);
    }
}
