//! The persisted model artifact — the training half's hand-off to serving.
//!
//! A pipeline run ends with K sparse PCs expressed in *reduced* (post-
//! elimination) coordinates plus the statistics needed to project new
//! documents onto them. This module freezes all of that into one
//! versioned binary file so `lsspca score` / `lsspca serve` can run
//! without re-touching the corpus:
//!
//! - the K sparse PCs with **original-space** feature indices,
//! - the kept→original elimination map and the survivors' means /
//!   standard deviations (for optional centering / normalization at
//!   scoring time),
//! - the survivors' word strings (so the server can score `{"terms":
//!   {word: count}}` payloads and explain `/topics` without a vocab
//!   file), and
//! - training metadata: corpus name, docs, original vocab size, seed,
//!   elimination λ̂, and an FNV hash of the full training vocabulary to
//!   detect scoring against a different vocabulary.
//!
//! Format (little-endian, `checkpoint.rs` style): magic `"LSPM"`,
//! u32 version, length-prefixed payload, trailing xor-fold checksum.
//! The loader validates magic, version, checksum and every internal
//! length/index invariant before returning — a corrupt artifact must
//! never score traffic.

use std::path::Path;

use crate::data::Vocab;
use crate::error::LsspcaError;

const MAGIC: &[u8; 4] = b"LSPM";
const VERSION: u32 = 1;

/// One sparse principal component in original-index space.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelPc {
    /// λ chosen by the cardinality search.
    pub lambda: f64,
    /// Problem-(1) objective at the solution.
    pub phi: f64,
    /// Explained variance on the (deflated) training covariance.
    pub explained_variance: f64,
    /// `(original feature index, loading)`, sorted by decreasing
    /// |loading| — the order the paper's topic tables print.
    pub loadings: Vec<(usize, f64)>,
}

/// A complete, self-contained scoring model.
///
/// # Example: save → load roundtrip
///
/// ```
/// use lsspca::model::{Model, ModelPc};
///
/// let model = Model {
///     corpus_name: "doctest".into(),
///     num_docs: 10,
///     n_features: 6,
///     vocab_hash: 0,
///     seed: 1,
///     elim_lambda: 0.5,
///     kept: vec![4, 2],
///     kept_means: vec![0.5, 0.25],
///     kept_stds: vec![1.0, 1.0],
///     kept_words: vec!["alpha".into(), "beta".into()],
///     pcs: vec![ModelPc {
///         lambda: 0.5,
///         phi: 1.0,
///         explained_variance: 1.0,
///         loadings: vec![(4, 0.8), (2, 0.6)],
///     }],
/// };
/// model.validate().unwrap();
/// let path = std::env::temp_dir()
///     .join(format!("lsspca_doctest_model_{}.lspm", std::process::id()));
/// model.save(&path).unwrap();
/// let back = Model::load(&path).unwrap();
/// assert_eq!(back, model); // bit-for-bit, checksum verified
/// # std::fs::remove_file(&path).ok();
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct Model {
    /// Corpus name or input path the model was trained on.
    pub corpus_name: String,
    /// Documents in the training corpus.
    pub num_docs: u64,
    /// Original vocabulary size n (pre-elimination feature count).
    pub n_features: usize,
    /// FNV-1a hash of the training vocabulary (0 when no vocab file).
    pub vocab_hash: u64,
    /// Corpus / generator seed.
    pub seed: u64,
    /// Elimination λ̂ used to build the reduced problem.
    pub elim_lambda: f64,
    /// Kept→original elimination map, in decreasing-variance order.
    pub kept: Vec<usize>,
    /// Per-kept-feature training mean (aligned with `kept`).
    pub kept_means: Vec<f64>,
    /// Per-kept-feature training standard deviation (population).
    pub kept_stds: Vec<f64>,
    /// Word strings of the kept features (aligned with `kept`).
    pub kept_words: Vec<String>,
    /// The sparse PCs, original-index space.
    pub pcs: Vec<ModelPc>,
}

/// FNV-1a over every vocabulary word separated by `\n` — cheap identity
/// check that a scoring-time vocabulary matches the training one.
pub fn vocab_hash(vocab: &Vocab) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    };
    for i in 0..vocab.len() {
        for b in vocab.word(i).as_bytes() {
            eat(*b);
        }
        eat(b'\n');
    }
    h
}

use crate::util::xor_fold_checksum as checksum;

// --- payload writer/reader helpers -----------------------------------------

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

/// Bounds-checked little-endian reader over the payload — every read
/// returns `Err` instead of panicking on truncated input.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, len: usize) -> Result<&'a [u8], LsspcaError> {
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| LsspcaError::io("model: truncated payload"))?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u64(&mut self) -> Result<u64, LsspcaError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, LsspcaError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length-prefixed count with a sanity cap: a corrupt length must not
    /// trigger a huge allocation before the per-element reads fail.
    fn count(&mut self, what: &str) -> Result<usize, LsspcaError> {
        let v = self.u64()? as usize;
        if v > self.buf.len() {
            return Err(LsspcaError::io(format!("model: implausible {what} count {v}")));
        }
        Ok(v)
    }

    fn str(&mut self) -> Result<String, LsspcaError> {
        let len = self.count("string length")?;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| LsspcaError::io("model: non-utf8 string"))
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

impl Model {
    /// Internal consistency checks shared by construction and loading.
    pub fn validate(&self) -> Result<(), LsspcaError> {
        let nk = self.kept.len();
        if self.kept_means.len() != nk || self.kept_stds.len() != nk || self.kept_words.len() != nk
        {
            return Err(LsspcaError::io("model: kept map / means / stds / words length mismatch"));
        }
        if self.pcs.is_empty() {
            return Err(LsspcaError::io("model: no components"));
        }
        let kept_set: std::collections::HashSet<usize> = self.kept.iter().copied().collect();
        for (i, &k) in self.kept.iter().enumerate() {
            if k >= self.n_features {
                return Err(LsspcaError::io(format!(
                    "model: kept[{i}]={k} out of range (n={})",
                    self.n_features
                )));
            }
        }
        if kept_set.len() != nk {
            return Err(LsspcaError::io("model: duplicate indices in kept map"));
        }
        for (k, pc) in self.pcs.iter().enumerate() {
            if pc.loadings.is_empty() {
                return Err(LsspcaError::io(format!("model: PC {} has empty support", k + 1)));
            }
            let mut seen = std::collections::HashSet::with_capacity(pc.loadings.len());
            for &(idx, w) in &pc.loadings {
                if !kept_set.contains(&idx) {
                    return Err(LsspcaError::io(format!(
                        "model: PC {} loads feature {idx} outside the kept set",
                        k + 1
                    )));
                }
                if !seen.insert(idx) {
                    // the scorer would double-count a repeated feature
                    return Err(LsspcaError::io(format!(
                        "model: PC {} loads feature {idx} twice",
                        k + 1
                    )));
                }
                if !w.is_finite() {
                    return Err(LsspcaError::io(format!(
                        "model: PC {} has a non-finite loading",
                        k + 1
                    )));
                }
            }
        }
        Ok(())
    }

    /// Number of components K.
    pub fn num_pcs(&self) -> usize {
        self.pcs.len()
    }

    /// Serialize to bytes (header + payload + checksum).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut p = Vec::new();
        put_str(&mut p, &self.corpus_name);
        put_u64(&mut p, self.num_docs);
        put_u64(&mut p, self.n_features as u64);
        put_u64(&mut p, self.vocab_hash);
        put_u64(&mut p, self.seed);
        put_f64(&mut p, self.elim_lambda);
        put_u64(&mut p, self.kept.len() as u64);
        for &k in &self.kept {
            put_u64(&mut p, k as u64);
        }
        for &m in &self.kept_means {
            put_f64(&mut p, m);
        }
        for &s in &self.kept_stds {
            put_f64(&mut p, s);
        }
        for w in &self.kept_words {
            put_str(&mut p, w);
        }
        put_u64(&mut p, self.pcs.len() as u64);
        for pc in &self.pcs {
            put_f64(&mut p, pc.lambda);
            put_f64(&mut p, pc.phi);
            put_f64(&mut p, pc.explained_variance);
            put_u64(&mut p, pc.loadings.len() as u64);
            for &(idx, w) in &pc.loadings {
                put_u64(&mut p, idx as u64);
                put_f64(&mut p, w);
            }
        }
        let mut out = Vec::with_capacity(16 + p.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&p);
        out.extend_from_slice(&checksum(&p).to_le_bytes());
        out
    }

    /// Parse from bytes; verifies magic, version, checksum and internal
    /// invariants.
    pub fn from_bytes(buf: &[u8]) -> Result<Model, LsspcaError> {
        if buf.len() < 4 + 4 + 8 || &buf[..4] != MAGIC {
            return Err(LsspcaError::io("model: bad magic or truncated header"));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(LsspcaError::io(format!("model: version {version}, want {VERSION}")));
        }
        let payload = &buf[8..buf.len() - 8];
        let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        if checksum(payload) != stored {
            return Err(LsspcaError::io("model: checksum mismatch (corrupt artifact)"));
        }
        let mut r = Reader::new(payload);
        let corpus_name = r.str()?;
        let num_docs = r.u64()?;
        let n_features = r.u64()? as usize;
        let vocab_hash = r.u64()?;
        let seed = r.u64()?;
        let elim_lambda = r.f64()?;
        let nk = r.count("kept")?;
        let mut kept = Vec::with_capacity(nk);
        for _ in 0..nk {
            kept.push(r.u64()? as usize);
        }
        let mut kept_means = Vec::with_capacity(nk);
        for _ in 0..nk {
            kept_means.push(r.f64()?);
        }
        let mut kept_stds = Vec::with_capacity(nk);
        for _ in 0..nk {
            kept_stds.push(r.f64()?);
        }
        let mut kept_words = Vec::with_capacity(nk);
        for _ in 0..nk {
            kept_words.push(r.str()?);
        }
        let npcs = r.count("pc")?;
        let mut pcs = Vec::with_capacity(npcs);
        for _ in 0..npcs {
            let lambda = r.f64()?;
            let phi = r.f64()?;
            let explained_variance = r.f64()?;
            let card = r.count("loading")?;
            let mut loadings = Vec::with_capacity(card);
            for _ in 0..card {
                let idx = r.u64()? as usize;
                let w = r.f64()?;
                loadings.push((idx, w));
            }
            pcs.push(ModelPc { lambda, phi, explained_variance, loadings });
        }
        if !r.done() {
            return Err(LsspcaError::io("model: trailing bytes in payload"));
        }
        let model = Model {
            corpus_name,
            num_docs,
            n_features,
            vocab_hash,
            seed,
            elim_lambda,
            kept,
            kept_means,
            kept_stds,
            kept_words,
            pcs,
        };
        model.validate()?;
        Ok(model)
    }

    /// Save to a file (creates parent directories). The write is
    /// crash-atomic so a live server hot-reloading this path never
    /// observes a partially written artifact.
    pub fn save(&self, path: &Path) -> Result<(), LsspcaError> {
        self.validate()?;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| LsspcaError::io_at(dir, format!("mkdir: {e}")))?;
            }
        }
        // Crash-atomic (tmp + fsync + rename): a concurrent reader — the
        // serving layer's hot-reload watcher in particular — sees either
        // the old artifact or the complete new one, never a torn hybrid.
        crate::util::atomic_write(path, "model", &self.to_bytes())
            .map_err(|e| LsspcaError::io_at(path, format!("write model: {e}")))
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Model, LsspcaError> {
        let buf = std::fs::read(path)
            .map_err(|e| LsspcaError::io_at(path, format!("open model: {e}")))?;
        Self::from_bytes(&buf).map_err(|e| LsspcaError::io_at(path, e.message().to_string()))
    }

    /// Word string for an original feature index, resolved through the
    /// kept map (`wNNNNN` fallback off the kept set).
    pub fn word_of(&self, orig_idx: usize) -> String {
        self.kept
            .iter()
            .position(|&k| k == orig_idx)
            .map(|p| self.kept_words[p].clone())
            .unwrap_or_else(|| format!("w{orig_idx}"))
    }

    /// Human-readable summary for `lsspca export`.
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut s = format!(
            "model: corpus={} docs={} n={} kept={} pcs={} (elim λ̂={:.4e}, vocab hash {:016x})\n",
            self.corpus_name,
            self.num_docs,
            self.n_features,
            self.kept.len(),
            self.pcs.len(),
            self.elim_lambda,
            self.vocab_hash,
        );
        for (k, pc) in self.pcs.iter().enumerate() {
            let words: Vec<String> = pc
                .loadings
                .iter()
                .take(8)
                .map(|&(i, w)| format!("{}:{w:+.3}", self.word_of(i)))
                .collect();
            let _ = writeln!(
                s,
                "  PC{}: card={} λ={:.4} φ={:.4} [{}]",
                k + 1,
                pc.loadings.len(),
                pc.lambda,
                pc.phi,
                words.join(", ")
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub(crate) fn sample_model(seed: u64) -> Model {
        let mut rng = Rng::seed_from(seed);
        let n = 500usize;
        let kept: Vec<usize> = (0..40).map(|i| i * 7 % n).collect();
        let mut pcs = Vec::new();
        for _ in 0..3 {
            let card = 3 + rng.below(4);
            let mut loadings: Vec<(usize, f64)> = rng
                .sample_indices(kept.len(), card)
                .into_iter()
                .map(|p| (kept[p], rng.gauss()))
                .collect();
            loadings.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
            pcs.push(ModelPc {
                lambda: rng.range_f64(0.1, 2.0),
                phi: rng.range_f64(0.0, 5.0),
                explained_variance: rng.range_f64(0.0, 5.0),
                loadings,
            });
        }
        Model {
            corpus_name: "unit-test".into(),
            num_docs: 1234,
            n_features: n,
            vocab_hash: 0xfeedbeef,
            seed,
            elim_lambda: 0.73,
            kept_means: (0..40).map(|_| rng.gauss()).collect(),
            kept_stds: (0..40).map(|_| rng.range_f64(0.1, 3.0)).collect(),
            kept_words: (0..40).map(|i| format!("word{i}")).collect(),
            kept,
            pcs,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_model_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_bitwise() {
        let m = sample_model(1);
        let p = tmp("rt.lspm");
        m.save(&p).unwrap();
        let got = Model::load(&p).unwrap();
        assert_eq!(got, m);
        // float fields compare bitwise through PartialEq on f64 only when
        // equal values; pin the bits explicitly for one series
        for (a, b) in got.kept_means.iter().zip(&m.kept_means) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corruption_rejected() {
        let m = sample_model(2);
        let bytes = m.to_bytes();
        // flip each of a spread of bytes; every flip must be caught by the
        // checksum (or magic/version check)
        for at in [0usize, 5, 16, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1] {
            let mut b = bytes.clone();
            b[at] ^= 0x40;
            assert!(Model::from_bytes(&b).is_err(), "flip at {at} accepted");
        }
        // truncation at any point must error, never panic
        for cut in [0, 3, 8, 20, bytes.len() / 3, bytes.len() - 1] {
            assert!(Model::from_bytes(&bytes[..cut]).is_err(), "truncated at {cut} accepted");
        }
    }

    #[test]
    fn version_mismatch_rejected() {
        let m = sample_model(3);
        let mut b = m.to_bytes();
        b[4..8].copy_from_slice(&99u32.to_le_bytes());
        let e = Model::from_bytes(&b).unwrap_err();
        assert!(matches!(e, LsspcaError::Io { .. }));
        assert!(e.to_string().contains("version"), "{e}");
    }

    #[test]
    fn validate_catches_inconsistencies() {
        let mut m = sample_model(4);
        m.kept_means.pop();
        assert!(m.validate().is_err());

        let mut m = sample_model(5);
        m.pcs[0].loadings[0].0 = m.n_features + 10; // outside kept set & range
        assert!(m.validate().is_err());

        let mut m = sample_model(6);
        m.pcs.clear();
        assert!(m.validate().is_err());

        let mut m = sample_model(7);
        m.kept[1] = m.kept[0]; // duplicate
        assert!(m.validate().is_err());

        let mut m = sample_model(9);
        let first = m.pcs[0].loadings[0];
        m.pcs[0].loadings.push(first); // same feature loaded twice in one PC
        let e = m.validate().unwrap_err();
        assert!(e.to_string().contains("twice"), "{e}");
    }

    #[test]
    fn vocab_hash_distinguishes() {
        let a = Vocab::new(vec!["alpha".into(), "beta".into()]);
        let b = Vocab::new(vec!["alpha".into(), "gamma".into()]);
        let c = Vocab::new(vec!["alphabeta".into()]); // separator must matter
        assert_ne!(vocab_hash(&a), vocab_hash(&b));
        assert_ne!(vocab_hash(&a), vocab_hash(&c));
        assert_eq!(vocab_hash(&a), vocab_hash(&a.clone()));
    }

    #[test]
    fn word_of_resolves_and_falls_back() {
        let m = sample_model(8);
        let orig = m.kept[3];
        assert_eq!(m.word_of(orig), "word3");
        // an index off the kept set gets the synthetic name
        let off = (0..m.n_features).find(|i| !m.kept.contains(i)).unwrap();
        assert_eq!(m.word_of(off), format!("w{off}"));
    }
}
