//! Persisted job state for kill-and-resume of the streaming passes.
//!
//! The variance pass over a PubMed-scale corpus runs for hours; a
//! SIGKILL at hour three used to restart it from byte zero. This module
//! persists the pass's *partial accumulators at chunk granularity*: a
//! `.lsjs` file records how many chunks have been folded into the master
//! accumulator plus the accumulator itself, keyed by the corpus digest
//! and the chunk size. On restart, [`crate::stream::resumable_variance_pass`]
//! reloads the state, skips the completed chunks, and continues folding —
//! and because the resumable pass merges per-chunk accumulators into the
//! master *in strict chunk-index order* (see `stream.rs`), the resumed
//! run's final [`crate::moments::FeatureVariances`] is **bitwise
//! identical** to an uninterrupted run's.
//!
//! Format (little-endian, the `checkpoint.rs` framing family): magic
//! `"LSJS"`, `u32` version, then the payload — `u64` corpus key, `u64`
//! kind ([`KIND_VARIANCE`]), `u64` chunk_docs, `u64` completed_chunks,
//! `u64` docs, `u64` nnz, `u64` n, then `n × (u64 n_obs, f64 mean,
//! f64 m2)` per-feature Welford triples — and a trailing xor-fold
//! checksum of the payload.
//!
//! Like the variance checkpoint, job state is advisory: a corrupt,
//! stale, or foreign file is *rejected* (never silently used) and the
//! pass simply starts over. Writes are crash-atomic with transient-I/O
//! retry, so the file on disk is always a complete, verified snapshot.
//!
//! The distributed corpus pass ([`crate::dist`]) persists a second kind
//! of state here: a [`DistManifest`] (`distjob_*.lsjs`) holding the
//! job identity, the corpus source, and the per-shard status table a
//! killed coordinator resumes from. Same framing family, same advisory
//! semantics.

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::error::LsspcaError;
use crate::moments::FeatureMoments;
use crate::util::stats::RunningStats;
use crate::util::xor_fold_checksum as checksum;
use crate::util::{atomic_write, faultinject, retry};

const MAGIC: &[u8; 4] = b"LSJS";
const VERSION: u32 = 1;
/// Fixed-size payload prefix: key, kind, chunk_docs, completed_chunks,
/// docs, nnz, n.
const HEADER_U64S: usize = 7;

/// Job kind: the per-feature variance pass (`FeatureMoments`
/// accumulator). Future kinds (e.g. the reduced-CSR pass) extend the
/// format without breaking this one.
pub const KIND_VARIANCE: u64 = 1;

/// Job kind: the reduced-documents CSR pass (`ReducedDocsAccum` over the
/// kept features). Used by the distributed shard layer; the
/// single-process `.lsjs` snapshot above remains variance-only.
pub const KIND_REDUCE: u64 = 2;

/// Job kind: an incremental append fold ([`crate::incr`]). Same payload
/// as [`KIND_VARIANCE`] — a `FeatureMoments` accumulator at chunk
/// granularity — but keyed by the *chained* corpus digest of the append
/// in flight, so it can never be confused with a cold variance pass.
pub const KIND_APPEND: u64 = 3;

/// A resumable pass's persisted position: everything needed to continue
/// folding from chunk `completed_chunks` as if never interrupted.
#[derive(Clone, Debug)]
pub struct JobState {
    /// Corpus digest ([`crate::checkpoint::corpus_key`]) the pass ran over.
    pub key: u64,
    /// Which pass this is ([`KIND_VARIANCE`]).
    pub kind: u64,
    /// Chunk size (documents) the pass streamed with. Resuming at a
    /// different chunk size would move chunk boundaries and change the
    /// merge order, so a mismatch is rejected as stale.
    pub chunk_docs: u64,
    /// Chunks fully merged into `moments`, in order: chunks
    /// `0..completed_chunks` are done, the pass resumes at
    /// `completed_chunks`.
    pub completed_chunks: u64,
    /// The master accumulator after merging exactly those chunks.
    pub moments: FeatureMoments,
}

/// Job-state file path for a corpus key inside a cache directory.
pub fn path_for(cache_dir: &Path, key: u64) -> PathBuf {
    cache_dir.join(format!("jobstate_{key:016x}.lsjs"))
}

/// Persist a snapshot crash-atomically (tmp + fsync + rename), retrying
/// transient I/O under the process [`retry::policy`]. Failures are
/// [`LsspcaError::Cache`]; retry exhaustion sets
/// [`LsspcaError::is_transient`].
pub fn save(path: &Path, state: &JobState) -> Result<(), LsspcaError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| LsspcaError::cache(format!("job state mkdir {}: {e}", dir.display())))?;
    }
    let stats = state.moments.stats();
    let n = stats.len();
    let mut bytes = Vec::with_capacity(8 + 8 * HEADER_U64S + 24 * n + 8);
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&VERSION.to_le_bytes());
    for v in [
        state.key,
        state.kind,
        state.chunk_docs,
        state.completed_chunks,
        state.moments.docs,
        state.moments.nnz,
        n as u64,
    ] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    for st in stats {
        bytes.extend_from_slice(&st.n.to_le_bytes());
        bytes.extend_from_slice(&st.mean.to_le_bytes());
        bytes.extend_from_slice(&st.m2.to_le_bytes());
    }
    let sum = checksum(&bytes[8..]);
    bytes.extend_from_slice(&sum.to_le_bytes());
    retry::with_retry(&retry::policy(), || atomic_write(path, "jobstate", &bytes)).map_err(|e| {
        let msg = e.describe(&format!("job state {}: write", path.display()));
        if e.transient { LsspcaError::cache_transient(msg) } else { LsspcaError::cache(msg) }
    })
}

/// Load a snapshot. `Ok(None)` when no file exists; `Err` on corruption
/// or on any identity mismatch — wrong corpus key, wrong kind, a
/// different `chunk_docs` (chunk boundaries would move), or a feature
/// count that contradicts the live corpus. A rejected file must never be
/// resumed from: the caller logs and starts the pass over.
pub fn load(
    path: &Path,
    key: u64,
    expected_n: usize,
    chunk_docs: u64,
) -> Result<Option<JobState>, LsspcaError> {
    load_kind(path, key, expected_n, chunk_docs, KIND_VARIANCE)
}

/// [`load`] for an explicit job kind: the variance pass resumes
/// [`KIND_VARIANCE`] snapshots, the incremental append fold
/// [`KIND_APPEND`] ones. A kind mismatch is an identity mismatch — the
/// file describes a different pass and is rejected, never resumed from.
pub fn load_kind(
    path: &Path,
    key: u64,
    expected_n: usize,
    chunk_docs: u64,
    want_kind: u64,
) -> Result<Option<JobState>, LsspcaError> {
    let buf = match retry::with_retry(&retry::policy(), || {
        let f = std::fs::File::open(path)?;
        let mut r = faultinject::wrap_read("jobstate", f);
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        Ok(buf)
    }) {
        Ok(buf) => buf,
        Err(e) if e.error.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            let msg = e.describe(&format!("job state read {}", path.display()));
            return Err(if e.transient {
                LsspcaError::cache_transient(msg)
            } else {
                LsspcaError::cache(msg)
            });
        }
    };
    if buf.len() < 8 + 8 * HEADER_U64S + 8 || &buf[..4] != MAGIC {
        return Err(LsspcaError::cache("job state: bad magic or truncated header"));
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(LsspcaError::cache(format!("job state: version {version}, want {VERSION}")));
    }
    let payload = &buf[8..buf.len() - 8];
    let stored_sum = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored_sum {
        return Err(LsspcaError::cache("job state: checksum mismatch (corrupt file)"));
    }
    let rd_u64 = |o: usize| u64::from_le_bytes(payload[o..o + 8].try_into().unwrap());
    let stored_key = rd_u64(0);
    if stored_key != key {
        return Err(LsspcaError::cache(format!(
            "job state: corpus key mismatch ({stored_key:#x} vs {key:#x}) — foreign job state"
        )));
    }
    let kind = rd_u64(8);
    if kind != want_kind {
        return Err(LsspcaError::cache(format!(
            "job state: kind mismatch (file has kind {kind}, want {want_kind}) — \
             state from a different pass"
        )));
    }
    let stored_chunk = rd_u64(16);
    if stored_chunk != chunk_docs {
        return Err(LsspcaError::cache(format!(
            "job state: chunk size mismatch (file has chunk_docs={stored_chunk}, run uses \
             {chunk_docs}) — chunk boundaries would move; stale job state"
        )));
    }
    let completed_chunks = rd_u64(24);
    let docs = rd_u64(32);
    let nnz = rd_u64(40);
    let n = rd_u64(48) as usize;
    if payload.len() != 8 * HEADER_U64S + 24 * n {
        return Err(LsspcaError::cache("job state: payload size mismatch"));
    }
    if n != expected_n {
        return Err(LsspcaError::cache(format!(
            "job state: dimension mismatch (file has n={n}, corpus has n={expected_n}) — \
             stale or foreign job state"
        )));
    }
    let base = 8 * HEADER_U64S;
    let stats: Vec<RunningStats> = (0..n)
        .map(|i| {
            let o = base + 24 * i;
            RunningStats {
                n: rd_u64(o),
                mean: f64::from_le_bytes(payload[o + 8..o + 16].try_into().unwrap()),
                m2: f64::from_le_bytes(payload[o + 16..o + 24].try_into().unwrap()),
            }
        })
        .collect();
    Ok(Some(JobState {
        key,
        kind,
        chunk_docs,
        completed_chunks,
        moments: FeatureMoments::from_parts(stats, docs, nnz),
    }))
}

/// Remove a snapshot (on successful pass completion). Missing file is
/// fine; other failures are logged by the caller, not fatal.
pub fn remove(path: &Path) -> std::io::Result<()> {
    match std::fs::remove_file(path) {
        Err(e) if e.kind() != std::io::ErrorKind::NotFound => Err(e),
        _ => Ok(()),
    }
}

// ---------------------------------------------------------------------------
// Distributed job manifest
// ---------------------------------------------------------------------------

const DIST_MAGIC: &[u8; 4] = b"LSJM";
const DIST_VERSION: u32 = 1;

/// Where the corpus a distributed job streams comes from. The manifest
/// carries the source so a worker process can reopen the *identical*
/// stream (same synthetic generator seed or same file) without any other
/// channel to the coordinator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CorpusSource {
    /// A deterministic synthetic corpus ([`crate::corpus::SynthCorpus`]).
    Synth {
        /// Preset name ([`crate::corpus::CorpusSpec::name`]).
        preset: String,
        /// Documents in the (possibly rescaled) spec.
        docs: u64,
        /// Vocabulary size of the spec.
        vocab: u64,
        /// Generator seed.
        seed: u64,
    },
    /// An on-disk UCI docword file.
    File {
        /// Path as the coordinator sees it (workers run on the same host).
        path: String,
    },
}

/// Lifecycle of one shard in the manifest's shard table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardStatus {
    /// Not yet completed (never ran, or its worker died mid-shard).
    Pending,
    /// Final shard result file written and verified.
    Done,
    /// Its worker exited with an error; retryable on the next run.
    Failed,
}

impl ShardStatus {
    fn to_u8(self) -> u8 {
        match self {
            ShardStatus::Pending => 0,
            ShardStatus::Done => 1,
            ShardStatus::Failed => 2,
        }
    }
    fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(ShardStatus::Pending),
            1 => Some(ShardStatus::Done),
            2 => Some(ShardStatus::Failed),
            _ => None,
        }
    }
}

/// One row of the manifest's shard table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    /// Current lifecycle state.
    pub status: ShardStatus,
    /// Worker launches so far (for operator visibility in `status`).
    pub attempts: u32,
}

/// Persisted state of one distributed corpus pass: the job identity
/// (corpus key, kind, geometry), everything a worker needs to reopen the
/// stream, and the shard table the coordinator checks off as workers
/// finish. A killed coordinator reloads this file and resumes from the
/// last completed shard; a mismatched identity means the file belongs to
/// a different job and is discarded, never resumed from.
///
/// Format (little-endian): magic `"LSJM"`, `u32` version, payload —
/// `u64` key, kind, chunk_docs, shard_docs, num_docs, n,
/// max_bad_records, the corpus source (`u8` tag then its fields; strings
/// are `u64` length + UTF-8 bytes), the dead-letter path string (empty =
/// none), `u64` kept count + `u32` kept feature ids, `u64` shard count +
/// per-shard `(u8 status, u32 attempts)` — then a trailing xor-fold
/// checksum of the payload.
#[derive(Clone, Debug, PartialEq)]
pub struct DistManifest {
    /// Corpus digest ([`crate::checkpoint::corpus_key`]).
    pub key: u64,
    /// Which pass: [`KIND_VARIANCE`] or [`KIND_REDUCE`].
    pub kind: u64,
    /// Chunk size (documents) every worker streams with.
    pub chunk_docs: u64,
    /// Effective shard size in documents (chunk-aligned; see
    /// [`crate::dist::plan::effective_shard_docs`]).
    pub shard_docs: u64,
    /// Total observed documents the plan partitions.
    pub num_docs: u64,
    /// Feature count (vocabulary size for variance, kept count for reduce
    /// is still the full `n`; workers validate against the live corpus).
    pub n: u64,
    /// How workers reopen the corpus stream.
    pub source: CorpusSource,
    /// Per-run dead-letter budget (`robust_max_bad_records`); 0 = strict.
    pub max_bad_records: u64,
    /// Main dead-letter file path (empty when quarantine is disabled).
    pub dead_letter: String,
    /// Kept feature ids for [`KIND_REDUCE`] (empty for variance).
    pub kept: Vec<u32>,
    /// Shard table in merge order.
    pub shards: Vec<ShardEntry>,
}

impl DistManifest {
    /// True when `other` describes the same job: every identity field
    /// matches (shard *statuses* are allowed to differ — that is the
    /// progress this file exists to persist).
    pub fn same_job(&self, other: &DistManifest) -> bool {
        self.key == other.key
            && self.kind == other.kind
            && self.chunk_docs == other.chunk_docs
            && self.shard_docs == other.shard_docs
            && self.num_docs == other.num_docs
            && self.n == other.n
            && self.source == other.source
            && self.max_bad_records == other.max_bad_records
            && self.dead_letter == other.dead_letter
            && self.kept == other.kept
            && self.shards.len() == other.shards.len()
    }
}

/// Manifest file path for a `(corpus key, kind)` pair in a cache dir.
pub fn dist_path_for(cache_dir: &Path, key: u64, kind: u64) -> PathBuf {
    cache_dir.join(format!("distjob_{key:016x}_k{kind}.lsjs"))
}

fn put_str(bytes: &mut Vec<u8>, s: &str) {
    bytes.extend_from_slice(&(s.len() as u64).to_le_bytes());
    bytes.extend_from_slice(s.as_bytes());
}

/// Persist a manifest crash-atomically under fault tag `tag`. The
/// coordinator uses `"distmanifest-init"` for the creation save and
/// `"distmanifest"` for the per-shard status updates, so
/// `wkill:distmanifest@…` deterministically kills it right after the
/// first shard completes (between shard merges) — each save is a fresh
/// write stream, so the offset alone cannot select the k-th save.
pub fn save_dist(path: &Path, m: &DistManifest, tag: &str) -> Result<(), LsspcaError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| LsspcaError::cache(format!("dist manifest mkdir {}: {e}", dir.display())))?;
    }
    let mut bytes = Vec::with_capacity(256 + 4 * m.kept.len() + 5 * m.shards.len());
    bytes.extend_from_slice(DIST_MAGIC);
    bytes.extend_from_slice(&DIST_VERSION.to_le_bytes());
    for v in [m.key, m.kind, m.chunk_docs, m.shard_docs, m.num_docs, m.n, m.max_bad_records] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    match &m.source {
        CorpusSource::Synth { preset, docs, vocab, seed } => {
            bytes.push(0);
            put_str(&mut bytes, preset);
            for v in [*docs, *vocab, *seed] {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        CorpusSource::File { path } => {
            bytes.push(1);
            put_str(&mut bytes, path);
        }
    }
    put_str(&mut bytes, &m.dead_letter);
    bytes.extend_from_slice(&(m.kept.len() as u64).to_le_bytes());
    for &f in &m.kept {
        bytes.extend_from_slice(&f.to_le_bytes());
    }
    bytes.extend_from_slice(&(m.shards.len() as u64).to_le_bytes());
    for s in &m.shards {
        bytes.push(s.status.to_u8());
        bytes.extend_from_slice(&s.attempts.to_le_bytes());
    }
    let sum = checksum(&bytes[8..]);
    bytes.extend_from_slice(&sum.to_le_bytes());
    retry::with_retry(&retry::policy(), || atomic_write(path, tag, &bytes)).map_err(|e| {
        let msg = e.describe(&format!("dist manifest {}: write", path.display()));
        if e.transient { LsspcaError::cache_transient(msg) } else { LsspcaError::cache(msg) }
    })
}

/// Load a manifest. `Ok(None)` when no file exists; `Err` on any
/// structural defect (bad magic/version/checksum, truncation, malformed
/// fields). Identity validation against the live job is the caller's:
/// the coordinator discards a non-[`DistManifest::same_job`] file and
/// starts fresh; a worker treats any mismatch as fatal.
pub fn load_dist(path: &Path) -> Result<Option<DistManifest>, LsspcaError> {
    let buf = match retry::with_retry(&retry::policy(), || {
        let f = std::fs::File::open(path)?;
        let mut r = faultinject::wrap_read("distmanifest", f);
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        Ok(buf)
    }) {
        Ok(buf) => buf,
        Err(e) if e.error.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            let msg = e.describe(&format!("dist manifest read {}", path.display()));
            return Err(if e.transient {
                LsspcaError::cache_transient(msg)
            } else {
                LsspcaError::cache(msg)
            });
        }
    };
    let bad = |what: &str| LsspcaError::cache(format!("dist manifest: {what}"));
    if buf.len() < 8 + 8 || &buf[..4] != DIST_MAGIC {
        return Err(bad("bad magic or truncated header"));
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != DIST_VERSION {
        return Err(bad(&format!("version {version}, want {DIST_VERSION}")));
    }
    let payload = &buf[8..buf.len() - 8];
    let stored_sum = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored_sum {
        return Err(bad("checksum mismatch (corrupt file)"));
    }
    struct Cur<'a> {
        p: &'a [u8],
        pos: usize,
    }
    impl<'a> Cur<'a> {
        fn take(&mut self, len: usize) -> Result<&'a [u8], LsspcaError> {
            if self.p.len() - self.pos < len {
                return Err(LsspcaError::cache("dist manifest: truncated payload"));
            }
            let s = &self.p[self.pos..self.pos + len];
            self.pos += len;
            Ok(s)
        }
        fn u64(&mut self) -> Result<u64, LsspcaError> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }
        fn u32(&mut self) -> Result<u32, LsspcaError> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }
        fn str(&mut self, label: &str) -> Result<String, LsspcaError> {
            let len = self.u64()?;
            if len > self.p.len() as u64 {
                return Err(LsspcaError::cache(format!("dist manifest: oversized {label}")));
            }
            String::from_utf8(self.take(len as usize)?.to_vec())
                .map_err(|_| LsspcaError::cache(format!("dist manifest: non-UTF-8 {label}")))
        }
    }
    let mut c = Cur { p: payload, pos: 0 };
    let key = c.u64()?;
    let kind = c.u64()?;
    if kind != KIND_VARIANCE && kind != KIND_REDUCE {
        return Err(bad(&format!("unknown kind {kind}")));
    }
    let chunk_docs = c.u64()?;
    let shard_docs = c.u64()?;
    let num_docs = c.u64()?;
    let n = c.u64()?;
    let max_bad_records = c.u64()?;
    let source = match c.take(1)?[0] {
        0 => {
            let preset = c.str("preset")?;
            CorpusSource::Synth { preset, docs: c.u64()?, vocab: c.u64()?, seed: c.u64()? }
        }
        1 => CorpusSource::File { path: c.str("path")? },
        t => return Err(bad(&format!("unknown corpus source tag {t}"))),
    };
    let dead_letter = c.str("dead-letter path")?;
    let kept_len = c.u64()? as usize;
    if kept_len > payload.len() {
        return Err(bad("oversized kept table"));
    }
    let mut kept = Vec::with_capacity(kept_len);
    for _ in 0..kept_len {
        kept.push(c.u32()?);
    }
    let num_shards = c.u64()? as usize;
    if num_shards > payload.len() {
        return Err(bad("oversized shard table"));
    }
    let mut shards = Vec::with_capacity(num_shards);
    for _ in 0..num_shards {
        let status = ShardStatus::from_u8(c.take(1)?[0])
            .ok_or_else(|| LsspcaError::cache("dist manifest: unknown shard status"))?;
        let attempts = c.u32()?;
        shards.push(ShardEntry { status, attempts });
    }
    if c.pos != payload.len() {
        return Err(bad("trailing bytes after shard table"));
    }
    Ok(Some(DistManifest {
        key,
        kind,
        chunk_docs,
        shard_docs,
        num_docs,
        n,
        source,
        max_bad_records,
        dead_letter,
        kept,
        shards,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> JobState {
        let mut rng = Rng::seed_from(seed);
        let stats: Vec<RunningStats> = (0..n)
            .map(|_| RunningStats {
                n: rng.below(100) as u64,
                mean: rng.gauss(),
                m2: rng.range_f64(0.0, 10.0),
            })
            .collect();
        JobState {
            key: crate::checkpoint::corpus_key("job:test"),
            kind: KIND_VARIANCE,
            chunk_docs: 128,
            completed_chunks: 9,
            moments: FeatureMoments::from_parts(stats, 1152, 3456),
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("lsspca_jobstate_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let js = sample(40, 1);
        let p = tmp("rt.lsjs");
        save(&p, &js).unwrap();
        let got = load(&p, js.key, 40, 128).unwrap().unwrap();
        assert_eq!(got.completed_chunks, 9);
        assert_eq!(got.moments.docs, 1152);
        assert_eq!(got.moments.nnz, 3456);
        for (a, b) in got.moments.stats().iter().zip(js.moments.stats()) {
            assert_eq!(a.n, b.n);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.m2.to_bits(), b.m2.to_bits());
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_none() {
        assert!(load(&tmp("nope.lsjs"), 1, 4, 128).unwrap().is_none());
    }

    #[test]
    fn foreign_and_stale_states_rejected() {
        let js = sample(10, 2);
        let p = tmp("stale.lsjs");
        save(&p, &js).unwrap();
        // wrong corpus
        let e = load(&p, js.key ^ 1, 10, 128).unwrap_err().to_string();
        assert!(e.contains("key mismatch"), "{e}");
        // wrong chunk size: boundaries would move
        let e = load(&p, js.key, 10, 64).unwrap_err().to_string();
        assert!(e.contains("chunk size mismatch"), "{e}");
        // wrong dimension
        let e = load(&p, js.key, 11, 128).unwrap_err().to_string();
        assert!(e.contains("dimension mismatch"), "{e}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corruption_rejected() {
        let js = sample(25, 3);
        let p = tmp("corrupt.lsjs");
        save(&p, &js).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let e = load(&p, js.key, 25, 128).unwrap_err();
        assert!(matches!(e, LsspcaError::Cache { .. }));
        assert!(e.to_string().contains("checksum"), "{e}");
        // truncation
        std::fs::write(&p, &bytes[..bytes.len() / 4]).unwrap();
        assert!(load(&p, js.key, 25, 128).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn file_bytes_are_stable() {
        // Pinned layout shared with python/tests/test_fault_mirror.py:
        // the identical example must produce the identical file image
        // (and so the identical trailing checksum) in both languages.
        let js = JobState {
            key: 0x1122334455667788,
            kind: KIND_VARIANCE,
            chunk_docs: 64,
            completed_chunks: 3,
            moments: FeatureMoments::from_parts(
                vec![
                    RunningStats { n: 5, mean: 1.5, m2: 0.25 },
                    RunningStats { n: 7, mean: -2.0, m2: 3.5 },
                ],
                192,
                1000,
            ),
        };
        let p = tmp("pin.lsjs");
        save(&p, &js).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(bytes.len(), 8 + 8 * HEADER_U64S + 24 * 2 + 8);
        let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        assert_eq!(sum, 0x17154AFD2A2C67C7, "checksum drifted from the Python mirror pin");
        use std::fmt::Write as _;
        let mut hex = String::with_capacity(2 * bytes.len());
        for b in &bytes {
            write!(hex, "{b:02x}").unwrap();
        }
        assert_eq!(
            hex,
            "4c534a530100000088776655443322110100000000000000400000000000000003000000000000\
             00c000000000000000e8030000000000000200000000000000050000000000000000000000000\
             0f83f000000000000d03f070000000000000000000000000000c00000000000000c40c7672c2a\
             fd4a1517"
        );
    }

    #[test]
    fn append_kind_roundtrips_and_kinds_do_not_mix() {
        let mut js = sample(12, 5);
        js.kind = KIND_APPEND;
        let p = tmp("append.lsjs");
        save(&p, &js).unwrap();
        // the right kind loads
        let got = load_kind(&p, js.key, 12, 128, KIND_APPEND).unwrap().unwrap();
        assert_eq!(got.kind, KIND_APPEND);
        assert_eq!(got.completed_chunks, js.completed_chunks);
        // a variance resume must reject an append snapshot, and vice versa
        let e = load(&p, js.key, 12, 128).unwrap_err().to_string();
        assert!(e.contains("kind mismatch"), "{e}");
        let v = sample(12, 5);
        save(&p, &v).unwrap();
        let e = load_kind(&p, v.key, 12, 128, KIND_APPEND).unwrap_err().to_string();
        assert!(e.contains("kind mismatch"), "{e}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn remove_is_idempotent() {
        let p = tmp("rm.lsjs");
        save(&p, &sample(4, 4)).unwrap();
        remove(&p).unwrap();
        remove(&p).unwrap();
        assert!(load(&p, 1, 4, 128).unwrap().is_none());
    }

    fn sample_manifest() -> DistManifest {
        DistManifest {
            key: crate::checkpoint::corpus_key("dist:test"),
            kind: KIND_REDUCE,
            chunk_docs: 64,
            shard_docs: 512,
            num_docs: 600,
            n: 1500,
            source: CorpusSource::Synth {
                preset: "nytimes".into(),
                docs: 600,
                vocab: 1500,
                seed: 42,
            },
            max_bad_records: 8,
            dead_letter: "/tmp/dlq.jsonl".into(),
            kept: vec![3, 7, 11, 999],
            shards: vec![
                ShardEntry { status: ShardStatus::Done, attempts: 1 },
                ShardEntry { status: ShardStatus::Failed, attempts: 2 },
                ShardEntry { status: ShardStatus::Pending, attempts: 0 },
            ],
        }
    }

    #[test]
    fn manifest_roundtrips_exactly() {
        let m = sample_manifest();
        let p = tmp("manifest.lsjs");
        save_dist(&p, &m, "distmanifest-init").unwrap();
        let got = load_dist(&p).unwrap().unwrap();
        assert_eq!(got, m);
        assert!(got.same_job(&m));
        // a file-source manifest roundtrips too
        let mut mf = m.clone();
        mf.source = CorpusSource::File { path: "data/docword.nytimes.txt".into() };
        mf.kept.clear();
        mf.kind = KIND_VARIANCE;
        save_dist(&p, &mf, "distmanifest").unwrap();
        assert_eq!(load_dist(&p).unwrap().unwrap(), mf);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn manifest_missing_file_is_none() {
        assert!(load_dist(&tmp("manifest_none.lsjs")).unwrap().is_none());
    }

    #[test]
    fn manifest_corruption_and_truncation_rejected() {
        let p = tmp("manifest_bad.lsjs");
        save_dist(&p, &sample_manifest(), "distmanifest").unwrap();
        let clean = std::fs::read(&p).unwrap();
        // flip a payload byte → checksum catches it
        let mut bytes = clean.clone();
        bytes[20] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let e = load_dist(&p).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");
        // truncate → bad magic/truncated or checksum error, never Ok
        std::fs::write(&p, &clean[..clean.len() / 3]).unwrap();
        assert!(load_dist(&p).is_err());
        // wrong magic
        let mut bytes = clean.clone();
        bytes[0] = b'X';
        std::fs::write(&p, &bytes).unwrap();
        let e = load_dist(&p).unwrap_err().to_string();
        assert!(e.contains("magic"), "{e}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn same_job_ignores_progress_but_not_identity() {
        let m = sample_manifest();
        let mut progressed = m.clone();
        progressed.shards[2].status = ShardStatus::Done;
        progressed.shards[2].attempts = 1;
        assert!(m.same_job(&progressed));
        let mut other = m.clone();
        other.chunk_docs = 32;
        assert!(!m.same_job(&other));
        let mut other = m.clone();
        other.source = CorpusSource::File { path: "x".into() };
        assert!(!m.same_job(&other));
        let mut other = m.clone();
        other.shards.pop();
        assert!(!m.same_job(&other));
    }

    #[test]
    fn manifest_path_embeds_key_and_kind() {
        let p = dist_path_for(Path::new("/cache"), 0xABCD, KIND_VARIANCE);
        assert_eq!(p, Path::new("/cache/distjob_000000000000abcd_k1.lsjs"));
    }

    #[test]
    fn manifest_bytes_are_stable() {
        // Pinned layout shared with python/tests/test_dist_mirror.py:
        // the identical example must produce the identical file image
        // (and so the identical trailing checksum) in both languages.
        let m = DistManifest {
            key: 0x1122334455667788,
            kind: KIND_REDUCE,
            chunk_docs: 64,
            shard_docs: 128,
            num_docs: 200,
            n: 1500,
            source: CorpusSource::Synth {
                preset: "nytimes".into(),
                docs: 200,
                vocab: 1500,
                seed: 7,
            },
            max_bad_records: 2,
            dead_letter: "dlq.jsonl".into(),
            kept: vec![2, 5],
            shards: vec![
                ShardEntry { status: ShardStatus::Done, attempts: 1 },
                ShardEntry { status: ShardStatus::Pending, attempts: 0 },
            ],
        };
        let p = tmp("manifest_pin.lsjs");
        save_dist(&p, &m, "distmanifest").unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(bytes.len(), 163);
        let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        assert_eq!(sum, 0x069566457F40FCA7, "checksum drifted from the Python mirror pin");
        use std::fmt::Write as _;
        let mut hex = String::with_capacity(2 * bytes.len());
        for b in &bytes {
            write!(hex, "{b:02x}").unwrap();
        }
        assert_eq!(
            hex,
            "4c534a4d0100000088776655443322110200000000000000400000000000000080000000000000\
             00c800000000000000dc0500000000000002000000000000000007000000000000006e7974696d\
             6573c800000000000000dc0500000000000007000000000000000900000000000000646c712e6a\
             736f6e6c02000000000000000200000005000000020000000000000001010000000000000000a7\
             fc407f45669506"
        );
    }
}
