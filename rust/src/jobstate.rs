//! Persisted job state for kill-and-resume of the streaming passes.
//!
//! The variance pass over a PubMed-scale corpus runs for hours; a
//! SIGKILL at hour three used to restart it from byte zero. This module
//! persists the pass's *partial accumulators at chunk granularity*: a
//! `.lsjs` file records how many chunks have been folded into the master
//! accumulator plus the accumulator itself, keyed by the corpus digest
//! and the chunk size. On restart, [`crate::stream::resumable_variance_pass`]
//! reloads the state, skips the completed chunks, and continues folding —
//! and because the resumable pass merges per-chunk accumulators into the
//! master *in strict chunk-index order* (see `stream.rs`), the resumed
//! run's final [`crate::moments::FeatureVariances`] is **bitwise
//! identical** to an uninterrupted run's.
//!
//! Format (little-endian, the `checkpoint.rs` framing family): magic
//! `"LSJS"`, `u32` version, then the payload — `u64` corpus key, `u64`
//! kind ([`KIND_VARIANCE`]), `u64` chunk_docs, `u64` completed_chunks,
//! `u64` docs, `u64` nnz, `u64` n, then `n × (u64 n_obs, f64 mean,
//! f64 m2)` per-feature Welford triples — and a trailing xor-fold
//! checksum of the payload.
//!
//! Like the variance checkpoint, job state is advisory: a corrupt,
//! stale, or foreign file is *rejected* (never silently used) and the
//! pass simply starts over. Writes are crash-atomic with transient-I/O
//! retry, so the file on disk is always a complete, verified snapshot.

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::error::LsspcaError;
use crate::moments::FeatureMoments;
use crate::util::stats::RunningStats;
use crate::util::xor_fold_checksum as checksum;
use crate::util::{atomic_write, faultinject, retry};

const MAGIC: &[u8; 4] = b"LSJS";
const VERSION: u32 = 1;
/// Fixed-size payload prefix: key, kind, chunk_docs, completed_chunks,
/// docs, nnz, n.
const HEADER_U64S: usize = 7;

/// Job kind: the per-feature variance pass (`FeatureMoments`
/// accumulator). Future kinds (e.g. the reduced-CSR pass) extend the
/// format without breaking this one.
pub const KIND_VARIANCE: u64 = 1;

/// A resumable pass's persisted position: everything needed to continue
/// folding from chunk `completed_chunks` as if never interrupted.
#[derive(Clone, Debug)]
pub struct JobState {
    /// Corpus digest ([`crate::checkpoint::corpus_key`]) the pass ran over.
    pub key: u64,
    /// Which pass this is ([`KIND_VARIANCE`]).
    pub kind: u64,
    /// Chunk size (documents) the pass streamed with. Resuming at a
    /// different chunk size would move chunk boundaries and change the
    /// merge order, so a mismatch is rejected as stale.
    pub chunk_docs: u64,
    /// Chunks fully merged into `moments`, in order: chunks
    /// `0..completed_chunks` are done, the pass resumes at
    /// `completed_chunks`.
    pub completed_chunks: u64,
    /// The master accumulator after merging exactly those chunks.
    pub moments: FeatureMoments,
}

/// Job-state file path for a corpus key inside a cache directory.
pub fn path_for(cache_dir: &Path, key: u64) -> PathBuf {
    cache_dir.join(format!("jobstate_{key:016x}.lsjs"))
}

/// Persist a snapshot crash-atomically (tmp + fsync + rename), retrying
/// transient I/O under the process [`retry::policy`]. Failures are
/// [`LsspcaError::Cache`]; retry exhaustion sets
/// [`LsspcaError::is_transient`].
pub fn save(path: &Path, state: &JobState) -> Result<(), LsspcaError> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| LsspcaError::cache(format!("job state mkdir {}: {e}", dir.display())))?;
    }
    let stats = state.moments.stats();
    let n = stats.len();
    let mut bytes = Vec::with_capacity(8 + 8 * HEADER_U64S + 24 * n + 8);
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&VERSION.to_le_bytes());
    for v in [
        state.key,
        state.kind,
        state.chunk_docs,
        state.completed_chunks,
        state.moments.docs,
        state.moments.nnz,
        n as u64,
    ] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    for st in stats {
        bytes.extend_from_slice(&st.n.to_le_bytes());
        bytes.extend_from_slice(&st.mean.to_le_bytes());
        bytes.extend_from_slice(&st.m2.to_le_bytes());
    }
    let sum = checksum(&bytes[8..]);
    bytes.extend_from_slice(&sum.to_le_bytes());
    retry::with_retry(&retry::policy(), || atomic_write(path, "jobstate", &bytes)).map_err(|e| {
        let msg = e.describe(&format!("job state {}: write", path.display()));
        if e.transient { LsspcaError::cache_transient(msg) } else { LsspcaError::cache(msg) }
    })
}

/// Load a snapshot. `Ok(None)` when no file exists; `Err` on corruption
/// or on any identity mismatch — wrong corpus key, wrong kind, a
/// different `chunk_docs` (chunk boundaries would move), or a feature
/// count that contradicts the live corpus. A rejected file must never be
/// resumed from: the caller logs and starts the pass over.
pub fn load(
    path: &Path,
    key: u64,
    expected_n: usize,
    chunk_docs: u64,
) -> Result<Option<JobState>, LsspcaError> {
    let buf = match retry::with_retry(&retry::policy(), || {
        let f = std::fs::File::open(path)?;
        let mut r = faultinject::wrap_read("jobstate", f);
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        Ok(buf)
    }) {
        Ok(buf) => buf,
        Err(e) if e.error.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            let msg = e.describe(&format!("job state read {}", path.display()));
            return Err(if e.transient {
                LsspcaError::cache_transient(msg)
            } else {
                LsspcaError::cache(msg)
            });
        }
    };
    if buf.len() < 8 + 8 * HEADER_U64S + 8 || &buf[..4] != MAGIC {
        return Err(LsspcaError::cache("job state: bad magic or truncated header"));
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(LsspcaError::cache(format!("job state: version {version}, want {VERSION}")));
    }
    let payload = &buf[8..buf.len() - 8];
    let stored_sum = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored_sum {
        return Err(LsspcaError::cache("job state: checksum mismatch (corrupt file)"));
    }
    let rd_u64 = |o: usize| u64::from_le_bytes(payload[o..o + 8].try_into().unwrap());
    let stored_key = rd_u64(0);
    if stored_key != key {
        return Err(LsspcaError::cache(format!(
            "job state: corpus key mismatch ({stored_key:#x} vs {key:#x}) — foreign job state"
        )));
    }
    let kind = rd_u64(8);
    if kind != KIND_VARIANCE {
        return Err(LsspcaError::cache(format!("job state: unknown kind {kind}")));
    }
    let stored_chunk = rd_u64(16);
    if stored_chunk != chunk_docs {
        return Err(LsspcaError::cache(format!(
            "job state: chunk size mismatch (file has chunk_docs={stored_chunk}, run uses \
             {chunk_docs}) — chunk boundaries would move; stale job state"
        )));
    }
    let completed_chunks = rd_u64(24);
    let docs = rd_u64(32);
    let nnz = rd_u64(40);
    let n = rd_u64(48) as usize;
    if payload.len() != 8 * HEADER_U64S + 24 * n {
        return Err(LsspcaError::cache("job state: payload size mismatch"));
    }
    if n != expected_n {
        return Err(LsspcaError::cache(format!(
            "job state: dimension mismatch (file has n={n}, corpus has n={expected_n}) — \
             stale or foreign job state"
        )));
    }
    let base = 8 * HEADER_U64S;
    let stats: Vec<RunningStats> = (0..n)
        .map(|i| {
            let o = base + 24 * i;
            RunningStats {
                n: rd_u64(o),
                mean: f64::from_le_bytes(payload[o + 8..o + 16].try_into().unwrap()),
                m2: f64::from_le_bytes(payload[o + 16..o + 24].try_into().unwrap()),
            }
        })
        .collect();
    Ok(Some(JobState {
        key,
        kind,
        chunk_docs,
        completed_chunks,
        moments: FeatureMoments::from_parts(stats, docs, nnz),
    }))
}

/// Remove a snapshot (on successful pass completion). Missing file is
/// fine; other failures are logged by the caller, not fatal.
pub fn remove(path: &Path) -> std::io::Result<()> {
    match std::fs::remove_file(path) {
        Err(e) if e.kind() != std::io::ErrorKind::NotFound => Err(e),
        _ => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> JobState {
        let mut rng = Rng::seed_from(seed);
        let stats: Vec<RunningStats> = (0..n)
            .map(|_| RunningStats {
                n: rng.below(100) as u64,
                mean: rng.gauss(),
                m2: rng.range_f64(0.0, 10.0),
            })
            .collect();
        JobState {
            key: crate::checkpoint::corpus_key("job:test"),
            kind: KIND_VARIANCE,
            chunk_docs: 128,
            completed_chunks: 9,
            moments: FeatureMoments::from_parts(stats, 1152, 3456),
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("lsspca_jobstate_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let js = sample(40, 1);
        let p = tmp("rt.lsjs");
        save(&p, &js).unwrap();
        let got = load(&p, js.key, 40, 128).unwrap().unwrap();
        assert_eq!(got.completed_chunks, 9);
        assert_eq!(got.moments.docs, 1152);
        assert_eq!(got.moments.nnz, 3456);
        for (a, b) in got.moments.stats().iter().zip(js.moments.stats()) {
            assert_eq!(a.n, b.n);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.m2.to_bits(), b.m2.to_bits());
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_none() {
        assert!(load(&tmp("nope.lsjs"), 1, 4, 128).unwrap().is_none());
    }

    #[test]
    fn foreign_and_stale_states_rejected() {
        let js = sample(10, 2);
        let p = tmp("stale.lsjs");
        save(&p, &js).unwrap();
        // wrong corpus
        let e = load(&p, js.key ^ 1, 10, 128).unwrap_err().to_string();
        assert!(e.contains("key mismatch"), "{e}");
        // wrong chunk size: boundaries would move
        let e = load(&p, js.key, 10, 64).unwrap_err().to_string();
        assert!(e.contains("chunk size mismatch"), "{e}");
        // wrong dimension
        let e = load(&p, js.key, 11, 128).unwrap_err().to_string();
        assert!(e.contains("dimension mismatch"), "{e}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corruption_rejected() {
        let js = sample(25, 3);
        let p = tmp("corrupt.lsjs");
        save(&p, &js).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let e = load(&p, js.key, 25, 128).unwrap_err();
        assert!(matches!(e, LsspcaError::Cache { .. }));
        assert!(e.to_string().contains("checksum"), "{e}");
        // truncation
        std::fs::write(&p, &bytes[..bytes.len() / 4]).unwrap();
        assert!(load(&p, js.key, 25, 128).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn file_bytes_are_stable() {
        // Pinned layout shared with python/tests/test_fault_mirror.py:
        // the identical example must produce the identical file image
        // (and so the identical trailing checksum) in both languages.
        let js = JobState {
            key: 0x1122334455667788,
            kind: KIND_VARIANCE,
            chunk_docs: 64,
            completed_chunks: 3,
            moments: FeatureMoments::from_parts(
                vec![
                    RunningStats { n: 5, mean: 1.5, m2: 0.25 },
                    RunningStats { n: 7, mean: -2.0, m2: 3.5 },
                ],
                192,
                1000,
            ),
        };
        let p = tmp("pin.lsjs");
        save(&p, &js).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(bytes.len(), 8 + 8 * HEADER_U64S + 24 * 2 + 8);
        let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        assert_eq!(sum, 0x17154AFD2A2C67C7, "checksum drifted from the Python mirror pin");
        use std::fmt::Write as _;
        let mut hex = String::with_capacity(2 * bytes.len());
        for b in &bytes {
            write!(hex, "{b:02x}").unwrap();
        }
        assert_eq!(
            hex,
            "4c534a530100000088776655443322110100000000000000400000000000000003000000000000\
             00c000000000000000e8030000000000000200000000000000050000000000000000000000000\
             0f83f000000000000d03f070000000000000000000000000000c00000000000000c40c7672c2a\
             fd4a1517"
        );
    }

    #[test]
    fn remove_is_idempotent() {
        let p = tmp("rm.lsjs");
        save(&p, &sample(4, 4)).unwrap();
        remove(&p).unwrap();
        remove(&p).unwrap();
        assert!(load(&p, 1, 4, 128).unwrap().is_none());
    }
}
