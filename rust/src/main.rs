//! `lsspca` — command-line entrypoint for the Large-Scale Sparse PCA
//! pipeline (Zhang & El Ghaoui, NIPS 2011 reproduction).
//!
//! ```text
//! lsspca run        --preset nytimes --pcs 5 --target-card 5     # full pipeline
//! lsspca gen        --preset pubmed --docs 100000 --out corpus.txt.gz
//! lsspca variances  --input corpus.txt.gz                        # Fig 2 profile
//! lsspca solve      --n 200 --lambda 0.5 --model spiked          # solver on synthetic Σ
//! lsspca artifacts  --dir artifacts                              # inspect AOT artifacts
//! ```

use std::path::{Path, PathBuf};

use lsspca::cli::{App, Args, CommandSpec, Parsed};
use lsspca::config::PipelineConfig;
use lsspca::coordinator::Pipeline;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::data::Vocab;
use lsspca::prelude::*;
use lsspca::solver::bca;
use lsspca::stream::{variance_pass_file, StreamOptions};
use lsspca::util::plot::AsciiPlot;
use lsspca::util::rng::Rng;

fn app() -> App {
    App::new("lsspca", "large-scale sparse PCA (NIPS 2011 reproduction)")
        .command(
            CommandSpec::new("run", "full pipeline: stream → eliminate → solve → topics")
                .opt("config", "", "TOML config file (flags override)")
                .opt("input", "", "docword file (empty = synthetic preset)")
                .opt("preset", "nytimes", "synthetic preset: nytimes|pubmed")
                .opt("docs", "0", "synthetic docs (0 = preset default)")
                .opt("vocab", "0", "synthetic vocab (0 = preset default)")
                .opt("seed", "20111212", "corpus seed")
                .opt("pcs", "5", "number of sparse PCs")
                .opt("target-card", "5", "target cardinality per PC")
                .opt("max-reduced", "512", "cap on reduced problem size")
                .opt("workers", "2", "moment-pass worker threads")
                .opt("threads", "", "solver worker threads (0 = all cores; empty = config value)")
                .opt("engine", "native", "solver engine: native|xla")
                .opt("cov-backend", "", "covariance backend: dense|gram (empty = config value)")
                .opt("row-cache-mb", "", "gram-backend row cache MiB (empty = config value)")
                .opt("artifacts", "artifacts", "artifact dir for --engine xla")
                .opt("cache-dir", "", "variance-checkpoint dir (reused across runs)")
                .switch("certify", "compute a dual optimality certificate per PC")
                .switch("profile", "print the timing profile"),
        )
        .command(
            CommandSpec::new("gen", "generate a synthetic corpus to disk (UCI docword format)")
                .req("out", "output path (.gz for gzip)")
                .opt("preset", "nytimes", "nytimes|pubmed")
                .opt("docs", "0", "documents (0 = preset default)")
                .opt("vocab", "0", "vocabulary (0 = preset default)")
                .opt("seed", "20111212", "seed"),
        )
        .command(
            CommandSpec::new("variances", "streamed variance profile of a docword file (Fig 2)")
                .req("input", "docword file")
                .opt("workers", "2", "worker threads")
                .opt("top", "20", "print the top-k features"),
        )
        .command(
            CommandSpec::new("solve", "run BCA on a synthetic covariance model")
                .opt("n", "100", "problem size")
                .opt("m", "300", "samples for the covariance model")
                .opt("model", "spiked", "spiked|gaussian")
                .opt("card", "10", "spike cardinality (spiked model)")
                .opt("lambda", "-1", "penalty λ (-1 = auto from variances)")
                .opt("sweeps", "20", "max BCA sweeps")
                .opt("seed", "7", "model seed"),
        )
        .command(
            CommandSpec::new("artifacts", "load and list AOT artifacts through PJRT")
                .opt("dir", "artifacts", "artifact directory"),
        )
        .command(
            CommandSpec::new(
                "bench",
                "hot-path benchmarks (qp_micro + fig1_speed scenarios) → BENCH_bca.json",
            )
            .opt("n", "512", "BCA problem size for the headline scenario")
            .opt("sweeps", "5", "fixed BCA sweeps K")
            .opt("threads", "4", "worker threads for the λ-search scaling scenario")
            .opt("out", "BENCH_bca.json", "output JSON path")
            .opt("covop-out", "BENCH_covop.json", "covariance-operator race output JSON path")
            .switch("quick", "smaller sizes / fewer repetitions"),
        )
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let mut cfg = if args.str("config").is_empty() {
        PipelineConfig::default()
    } else {
        PipelineConfig::load(Path::new(&args.str("config")))?
    };
    // flags override config-file values
    if !args.str("input").is_empty() {
        cfg.input = args.str("input");
    }
    cfg.synth_preset = args.str("preset");
    if args.usize("docs")? > 0 {
        cfg.synth_docs = args.usize("docs")?;
    }
    if args.usize("vocab")? > 0 {
        cfg.synth_vocab = args.usize("vocab")?;
    }
    cfg.seed = args.u64("seed")?;
    cfg.num_pcs = args.usize("pcs")?;
    cfg.target_card = args.usize("target-card")?;
    cfg.max_reduced = args.usize("max-reduced")?;
    cfg.workers = args.usize("workers")?;
    // Empty default keeps the config file's solver.threads; an explicit
    // flag (including 0 = all cores) overrides it.
    if !args.str("threads").is_empty() {
        cfg.threads = args.usize("threads")?;
    }
    cfg.engine = args.str("engine");
    if !args.str("cov-backend").is_empty() {
        cfg.cov_backend = args.str("cov-backend");
    }
    if !args.str("row-cache-mb").is_empty() {
        cfg.row_cache_mb = args.usize("row-cache-mb")?;
    }
    cfg.artifacts_dir = args.str("artifacts");
    if !args.str("cache-dir").is_empty() {
        cfg.cache_dir = args.str("cache-dir");
    }
    cfg.certify = cfg.certify || args.switch("certify");
    cfg.validate()?;

    let report = Pipeline::new(cfg).run()?;
    println!("\n# {} — sparse PCA report", report.corpus_name);
    println!(
        "docs={} vocab={} nnz={} | reduced n̂={} ({}x reduction, λ̂={:.4e}{})",
        report.num_docs,
        report.vocab_size,
        report.nnz,
        report.reduced_size,
        report.reduction_factor as u64,
        report.elim_lambda,
        if report.elim_capped { ", capped" } else { "" }
    );
    println!("\n{}", report.topic_table);
    for (k, c) in report.components.iter().enumerate() {
        let cert = c
            .certificate_gap
            .map(|g| format!(" gap≤{g:.2e}"))
            .unwrap_or_default();
        println!(
            "PC{}: card={} λ={:.4} φ={:.4} explained={:.4} ({:.2}s){cert}",
            k + 1,
            c.pc.cardinality(),
            c.lambda,
            c.phi,
            c.explained_variance,
            c.seconds
        );
    }
    println!("\ntotal: {:.2}s", report.total_seconds);
    if args.switch("profile") {
        println!("\n{}", report.profile);
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<(), String> {
    let spec = CorpusSpec::preset(&args.str("preset"))
        .ok_or("unknown preset")?
        .scaled(args.usize("docs")?, args.usize("vocab")?);
    let corpus = SynthCorpus::new(spec, args.u64("seed")?);
    let out = PathBuf::from(args.str("out"));
    let t = lsspca::util::Timer::start();
    let hdr = corpus.write_docword(&out)?;
    println!(
        "wrote {}: D={} W={} NNZ={} in {:.1}s (+ vocab at {})",
        out.display(),
        hdr.num_docs,
        hdr.vocab_size,
        hdr.nnz,
        t.secs(),
        out.with_extension("vocab").display()
    );
    Ok(())
}

fn cmd_variances(args: &Args) -> Result<(), String> {
    let input = PathBuf::from(args.str("input"));
    let opts = StreamOptions { workers: args.usize("workers")?, ..Default::default() };
    let (hdr, fv, stats) = variance_pass_file(&input, opts)?;
    let sorted = fv.sorted_variances();
    println!(
        "D={} W={} NNZ={} | pass took {:.2}s with {} workers",
        hdr.num_docs, hdr.vocab_size, hdr.nnz, stats.seconds, opts.workers
    );
    let pts: Vec<(f64, f64)> = sorted
        .iter()
        .enumerate()
        .filter(|&(_, &v)| v > 0.0)
        .map(|(i, &v)| ((i + 1) as f64, v))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("sorted word variances (cf. paper Fig 2)")
            .logx()
            .logy()
            .series("variance", '*', &pts)
            .render()
    );
    let vocab_path = input.with_extension("vocab");
    let vocab = if vocab_path.exists() { Vocab::load(&vocab_path)? } else { Vocab::default() };
    println!("top features by variance:");
    for (rank, (idx, var)) in fv.ranked().into_iter().take(args.usize("top")?).enumerate() {
        println!("  {:>3}. {:<20} {var:.4}", rank + 1, vocab.word(idx));
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<(), String> {
    let n = args.usize("n")?;
    let m = args.usize("m")?;
    let mut rng = Rng::seed_from(args.u64("seed")?);
    let sigma = match args.str("model").as_str() {
        "spiked" => {
            lsspca::corpus::spiked_covariance(n, m, args.usize("card")?.min(n), 2.0, &mut rng)
        }
        "gaussian" => lsspca::corpus::gaussian_factor_cov(n, m, &mut rng),
        other => return Err(format!("unknown model '{other}'")),
    };
    let mut lambda = args.f64("lambda")?;
    if lambda < 0.0 {
        let diags: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        lambda = lsspca::elim::lambda_for_survivors(&diags, (2 * args.usize("card")?).max(10));
        println!("auto λ = {lambda:.4}");
    }
    let opts = BcaOptions { max_sweeps: args.usize("sweeps")?, ..Default::default() };
    let sol = bca::solve(&sigma, lambda, &opts);
    let pc = lsspca::solver::extract::leading_sparse_pc(&sol.z, 1e-4);
    println!(
        "φ={:.6} sweeps={} final_delta={:.2e} time={:.2}s",
        sol.phi, sol.sweeps, sol.final_delta, sol.seconds
    );
    println!("support ({}): {:?}", pc.cardinality(), pc.support);
    let series: Vec<(f64, f64)> = sol
        .history
        .iter()
        .map(|h| (h.seconds.max(1e-6), h.objective))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("objective vs time")
            .series("BCA", 'o', &series)
            .render()
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = PathBuf::from(args.str("dir"));
    let mut rt = lsspca::runtime::Runtime::new().map_err(|e| format!("{e:#}"))?;
    let names = rt.load_dir(&dir).map_err(|e| format!("{e:#}"))?;
    println!("loaded {} artifacts from {}:", names.len(), dir.display());
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts(_args: &Args) -> Result<(), String> {
    Err("this build has no XLA support (rebuild with --features xla)".into())
}

/// Time one closure: min wall-clock over `reps` runs (first run warms).
fn time_min<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = lsspca::util::Timer::start();
        lsspca::util::bench::black_box(f());
        best = best.min(t.secs());
    }
    best
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    use lsspca::solver::lambda::{search, LambdaSearchOptions};
    use lsspca::solver::qp::{self, QpOptions};
    use lsspca::util::bench::{metric, section};

    let quick = args.switch("quick");
    let n = if quick { args.usize("n")?.min(128) } else { args.usize("n")? };
    let sweeps = args.usize("sweeps")?;
    let threads = args.usize("threads")?.max(1);
    let reps = if quick { 1 } else { 2 };
    let mut rng = Rng::seed_from(20111212);
    let mut json = String::from("{\n");

    // --- qp_micro: cold vs warm-started/active-set box-QP ----------------
    section("qp_micro — box-QP coordinate descent, cold vs warm");
    json.push_str("  \"qp_micro\": [\n");
    let qp_sizes: &[usize] = if quick { &[64, 128] } else { &[64, 128, 256, 512] };
    for (idx, &qn) in qp_sizes.iter().enumerate() {
        let y = SymMat::random_psd(qn, qn / 2 + 4, 0.05, &mut rng);
        let s = rng.gauss_vec(qn);
        let lambda = 0.3;
        let opts = QpOptions::default();
        let radius = vec![lambda; qn];
        let cold = time_min(reps + 1, || {
            let mut u = Vec::new();
            let mut w = Vec::new();
            qp::solve_masked(&y, &s, &radius, None, opts, &mut u, &mut w).r_squared
        });
        // warm re-solve, as the BCA outer loop sees it from sweep 2 on
        let prev = qp::solve(&y, &s, lambda, opts).u;
        let warm = time_min(reps + 1, || {
            let mut u = Vec::new();
            let mut w = Vec::new();
            let mut active = Vec::new();
            qp::solve_masked_warm(
                &y, &s, &radius, None, opts, Some(&prev), &mut u, &mut w, &mut active,
            )
            .r_squared
        });
        metric(&format!("qp.n{qn}.cold_secs"), format!("{cold:.6}"));
        metric(&format!("qp.n{qn}.warm_secs"), format!("{warm:.6}"));
        metric(&format!("qp.n{qn}.speedup"), format!("{:.2}", cold / warm.max(1e-12)));
        json.push_str(&format!(
            "    {{\"n\": {qn}, \"cold_secs\": {cold:.6}, \"warm_secs\": {warm:.6}, \
             \"speedup\": {:.3}}}{}\n",
            cold / warm.max(1e-12),
            if idx + 1 == qp_sizes.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");

    // --- fig1_speed headline: BCA at n, K sweeps, cold/serial vs hot ------
    // Paper regime: a strong cardinality-5 spike. BCA then concentrates X,
    // the column QPs become ill-conditioned, and cold starts pay heavily —
    // exactly the case the workspace exists for.
    section(&format!("fig1_speed — BCA n={n}, K={sweeps}: reference vs workspace"));
    let sigma = lsspca::corpus::spiked_covariance(n, 2 * n, 5, 10.0, &mut rng);
    let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, 3 * n / 4);
    let opts = BcaOptions {
        track_history: false,
        ..BcaOptions::fixed_sweeps(sweeps)
    };
    // Single timed run each (solves are seconds-scale at n = 512); φ comes
    // from the same runs, so equivalence is measured on what was timed.
    let t = lsspca::util::Timer::start();
    let phi_ref = bca::solve_reference(&sigma, lambda, &opts).phi;
    let ref_secs = t.secs();
    let t = lsspca::util::Timer::start();
    let phi_ws = bca::solve(&sigma, lambda, &opts).phi;
    let ws_secs = t.secs();
    let bca_speedup = ref_secs / ws_secs.max(1e-12);
    metric("bca.reference_secs", format!("{ref_secs:.4}"));
    metric("bca.workspace_secs", format!("{ws_secs:.4}"));
    metric("bca.speedup", format!("{bca_speedup:.2}"));
    metric("bca.phi_abs_diff", format!("{:.3e}", (phi_ref - phi_ws).abs()));
    json.push_str(&format!(
        "  \"bca_n{n}\": {{\"n\": {n}, \"sweeps\": {sweeps}, \"reference_secs\": {ref_secs:.6}, \
         \"workspace_secs\": {ws_secs:.6}, \"speedup\": {bca_speedup:.3}, \
         \"phi_abs_diff\": {:.3e}}},\n",
        (phi_ref - phi_ws).abs()
    ));

    // --- λ-search thread scaling ------------------------------------------
    section(&format!("lambda_search — serial vs {threads} threads (same probe schedule)"));
    let ln = if quick { 96 } else { 256.min(n) };
    let lsigma = lsspca::corpus::spiked_covariance(ln, 2 * ln, (ln / 10).max(4), 3.0, &mut rng);
    let mk_opts = |t: usize| LambdaSearchOptions {
        target_card: (ln / 12).max(5),
        slack: 1,
        max_evals: 8,
        probes_per_round: 4,
        threads: t,
        bca: BcaOptions { max_sweeps: sweeps, track_history: false, ..Default::default() },
        ..Default::default()
    };
    let serial_secs = time_min(reps, || search(&lsigma, &mk_opts(1)).lambda);
    let par_secs = time_min(reps, || search(&lsigma, &mk_opts(threads)).lambda);
    let serial_res = search(&lsigma, &mk_opts(1));
    let par_res = search(&lsigma, &mk_opts(threads));
    let identical = serial_res.lambda == par_res.lambda
        && serial_res.solution.phi == par_res.solution.phi;
    let ls_speedup = serial_secs / par_secs.max(1e-12);
    metric("lambda_search.serial_secs", format!("{serial_secs:.4}"));
    metric("lambda_search.parallel_secs", format!("{par_secs:.4}"));
    metric("lambda_search.speedup", format!("{ls_speedup:.2}"));
    metric("lambda_search.identical_result", format!("{identical}"));
    json.push_str(&format!(
        "  \"lambda_search\": {{\"n\": {ln}, \"threads\": {threads}, \
         \"serial_secs\": {serial_secs:.6}, \"parallel_secs\": {par_secs:.6}, \
         \"speedup\": {ls_speedup:.3}, \"identical_result\": {identical}}}\n"
    ));
    json.push_str("}\n");

    let out = PathBuf::from(args.str("out"));
    std::fs::write(&out, &json).map_err(|e| format!("writing {}: {e}", out.display()))?;
    println!("\nwrote {}", out.display());

    // --- covariance-operator races → BENCH_covop.json ---------------------
    use lsspca::covop::{CovOp, DenseCov, GramCov};

    let mut cj = String::from("{\n  \"matvec_row_gather\": [\n");
    let covop_sizes: &[usize] = if quick { &[256, 1024] } else { &[512, 4096] };
    section("covop — dense vs implicit-Gram covariance operator");
    for (idx, &nhat) in covop_sizes.iter().enumerate() {
        let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(4 * nhat, nhat);
        let corpus = lsspca::corpus::SynthCorpus::new(spec, 20111212);
        let csr = corpus.to_csr();
        let t = lsspca::util::Timer::start();
        let gram = GramCov::new(csr, (4 * nhat) as u64, 64);
        let gram_build = t.secs();
        let x: Vec<f64> = (0..nhat).map(|_| rng.gauss()).collect();
        let mut y = vec![0.0; nhat];
        let mv_gram = time_min(reps + 1, || gram.matvec(&x, &mut y));
        // Row gathers over a spread sample: first touch (sparse merge)
        // vs repeat (cache hit) — measured before anything else warms
        // the cache.
        let sample: Vec<usize> = (0..32).map(|k| (k * nhat / 32) % nhat).collect();
        let mut buf = vec![0.0; nhat];
        let t = lsspca::util::Timer::start();
        for &j in &sample {
            gram.row_into(j, &mut buf);
        }
        let rg_gram_cold = t.secs();
        let rg_gram_warm = time_min(reps + 1, || {
            for &j in &sample {
                gram.row_into(j, &mut buf);
            }
        });
        // Dense operator assembled through the operator interface: one
        // n̂ × n̂ buffer (the streaming CovAccum path holds a wave of
        // partial accumulators, which at n̂ = 4096 would be GBs).
        let t = lsspca::util::Timer::start();
        let dense = DenseCov::new(gram.materialize_full());
        let dense_build = t.secs();
        let mv_dense = time_min(reps + 1, || dense.matvec(&x, &mut y));
        let rg_dense = time_min(reps + 1, || {
            for &j in &sample {
                dense.row_into(j, &mut buf);
            }
        });
        metric(&format!("covop.n{nhat}.dense_build_secs"), format!("{dense_build:.4}"));
        metric(&format!("covop.n{nhat}.gram_build_secs"), format!("{gram_build:.4}"));
        metric(&format!("covop.n{nhat}.matvec_dense_secs"), format!("{mv_dense:.6}"));
        metric(&format!("covop.n{nhat}.matvec_gram_secs"), format!("{mv_gram:.6}"));
        metric(&format!("covop.n{nhat}.rowgather32_dense_secs"), format!("{rg_dense:.6}"));
        metric(&format!("covop.n{nhat}.rowgather32_gram_cold_secs"), format!("{rg_gram_cold:.6}"));
        metric(&format!("covop.n{nhat}.rowgather32_gram_warm_secs"), format!("{rg_gram_warm:.6}"));
        cj.push_str(&format!(
            "    {{\"nhat\": {nhat}, \"dense_build_secs\": {dense_build:.6}, \
             \"gram_build_secs\": {gram_build:.6}, \"matvec_dense_secs\": {mv_dense:.6}, \
             \"matvec_gram_secs\": {mv_gram:.6}, \"rowgather32_dense_secs\": {rg_dense:.6}, \
             \"rowgather32_gram_cold_secs\": {rg_gram_cold:.6}, \
             \"rowgather32_gram_warm_secs\": {rg_gram_warm:.6}}}{}\n",
            if idx + 1 == covop_sizes.len() { "" } else { "," }
        ));
    }
    cj.push_str("  ],\n");

    // λ-search with and without per-λ nested-elimination masks.
    section("covop — λ-search with vs without per-λ elimination masks");
    let mn = if quick { 128 } else { 256 };
    let msigma = lsspca::corpus::spiked_covariance(mn, 2 * mn, 5, 6.0, &mut rng);
    let mk_mask_opts = |masks: bool| LambdaSearchOptions {
        target_card: 5,
        slack: 1,
        max_evals: 8,
        per_lambda_elim: masks,
        bca: BcaOptions { max_sweeps: sweeps, track_history: false, ..Default::default() },
        ..Default::default()
    };
    let masked_secs = time_min(reps, || search(&msigma, &mk_mask_opts(true)).lambda);
    let unmasked_secs = time_min(reps, || search(&msigma, &mk_mask_opts(false)).lambda);
    let mask_speedup = unmasked_secs / masked_secs.max(1e-12);
    metric("covop.lambda_search.masked_secs", format!("{masked_secs:.4}"));
    metric("covop.lambda_search.unmasked_secs", format!("{unmasked_secs:.4}"));
    metric("covop.lambda_search.mask_speedup", format!("{mask_speedup:.2}"));
    cj.push_str(&format!(
        "  \"lambda_search_masks\": {{\"n\": {mn}, \"masked_secs\": {masked_secs:.6}, \
         \"unmasked_secs\": {unmasked_secs:.6}, \"speedup\": {mask_speedup:.3}}}\n}}\n"
    ));

    let covop_out = PathBuf::from(args.str("covop-out"));
    std::fs::write(&covop_out, &cj)
        .map_err(|e| format!("writing {}: {e}", covop_out.display()))?;
    println!("wrote {}", covop_out.display());
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match parsed {
        Parsed::Help(text) => {
            println!("{text}");
            Ok(())
        }
        Parsed::Command(name, args) => match name.as_str() {
            "run" => cmd_run(&args),
            "gen" => cmd_gen(&args),
            "variances" => cmd_variances(&args),
            "solve" => cmd_solve(&args),
            "artifacts" => cmd_artifacts(&args),
            "bench" => cmd_bench(&args),
            _ => unreachable!("parser rejects unknown commands"),
        },
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
