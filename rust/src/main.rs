//! `lsspca` — command-line entrypoint for the Large-Scale Sparse PCA
//! pipeline (Zhang & El Ghaoui, NIPS 2011 reproduction).
//!
//! ```text
//! lsspca run        --preset nytimes --pcs 5 --target-card 5     # full pipeline
//! lsspca gen        --preset pubmed --docs 100000 --out corpus.txt.gz
//! lsspca variances  --input corpus.txt.gz                        # Fig 2 profile
//! lsspca solve      --n 200 --lambda 0.5 --model spiked          # solver on synthetic Σ
//! lsspca export     --model-out model.lspm                       # train → artifact
//! lsspca score      --model model.lspm --input new.txt.gz        # batch projection
//! lsspca serve      --model model.lspm --addr 127.0.0.1:7878     # HTTP scoring
//! lsspca watch      --input corpus.txt --model-out model.lspm    # append→refit daemon
//! lsspca dlq        --path deadletter.jsonl --retry              # inspect quarantine
//! lsspca worker     --manifest distjob.lsjs --shard 0            # dist-pass worker (internal)
//! lsspca artifacts  --dir artifacts                              # inspect AOT artifacts
//! lsspca bench      --compare BENCH_baseline.json                # perf-regression gate
//! ```

use std::path::{Path, PathBuf};

use std::sync::Arc;

use lsspca::cli::{App, Args, CommandSpec, Parsed};
use lsspca::config::PipelineConfig;
use lsspca::coordinator::Pipeline;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::data::Vocab;
use lsspca::prelude::*;
use lsspca::score::{score_file_observed, BatchOptions};
use lsspca::session::{NoopProgress, StderrProgress};
use lsspca::solver::bca;
use lsspca::stream::{variance_pass_file, StreamOptions};
use lsspca::util::json::Json;
use lsspca::util::plot::AsciiPlot;
use lsspca::util::rng::Rng;

/// The training flags shared verbatim by `run` and `export` (parsed by
/// [`pipeline_config_from_args`] — keep the two in sync by construction).
fn with_training_flags(spec: CommandSpec) -> CommandSpec {
    spec.opt("config", "", "TOML config file (flags override)")
        .opt("input", "", "docword file (empty = synthetic preset)")
        .opt("preset", "nytimes", "synthetic preset: nytimes|pubmed")
        .opt("docs", "0", "synthetic docs (0 = preset default)")
        .opt("vocab", "0", "synthetic vocab (0 = preset default)")
        .opt("seed", "20111212", "corpus seed")
        .opt("pcs", "5", "number of sparse PCs")
        .opt("target-card", "5", "target cardinality per PC")
        .opt("max-reduced", "512", "cap on reduced problem size")
        .opt("workers", "2", "moment-pass worker threads")
        .opt("threads", "", "solver worker threads (0 = all cores; empty = config value)")
        .opt("engine", "native", "solver engine: native|xla")
        .opt("kernels", "", "SIMD kernel tier: auto|scalar|avx2|neon (empty = config value)")
        .opt("cov-backend", "", "covariance backend: dense|gram|disk|auto (empty = config value)")
        .opt("row-cache-mb", "", "gram-backend row cache MiB (empty = config value)")
        .opt("memory-budget-mb", "", "covariance memory budget MiB, 0 = unlimited (empty = config)")
        .opt("shard-mb", "", "disk-backend shard size MiB (empty = config value)")
        .opt("artifacts", "artifacts", "artifact dir for --engine xla")
        .opt("cache-dir", "", "variance-checkpoint dir (reused across runs)")
        .opt("save-model", "", "also write the scoring model artifact here")
        .opt("max-bad-records", "", "quarantine up to N malformed records (empty = config; 0 = strict abort)")
        .opt("dead-letter-path", "", "dead-letter queue path (empty = config value or auto)")
        .opt("retry-attempts", "", "transient-I/O retry attempts (empty = config value)")
        .opt("retry-base-ms", "", "retry backoff base delay in ms (empty = config value)")
        .opt("job-state", "", "resumable job state: on|off (empty = config value)")
        .opt("job-state-chunks", "", "chunks between job-state checkpoints (empty = config value)")
        .opt("faults", "", "deterministic fault-injection plan (testing; empty = config value)")
        .opt("dist-workers", "", "distributed-pass worker processes, 0 = in-process (empty = config)")
        .opt("dist-shard-docs", "", "docs per distributed shard, 0 = auto (empty = config value)")
        .switch("fast-math", "allow reassociating FMA kernels (faster, not bitwise-reproducible)")
        .switch("certify", "compute a dual optimality certificate per PC")
}

fn app() -> App {
    App::new("lsspca", "large-scale sparse PCA (NIPS 2011 reproduction)")
        .command(
            with_training_flags(CommandSpec::new(
                "run",
                "full pipeline: stream → eliminate → solve → topics",
            ))
            .switch("profile", "print the timing profile")
            .switch("progress", "print live stage progress to stderr"),
        )
        .command(
            with_training_flags(CommandSpec::new(
                "export",
                "train and write the scoring model artifact (.lspm)",
            ))
            .opt("model-out", "", "artifact path (empty = config save_path or model.lspm)"),
        )
        .command(
            CommandSpec::new("score", "batch-score a docword file with a model artifact")
                .req("model", "model artifact (.lspm) from `lsspca export`")
                .req("input", "docword file to score (.gz supported)")
                .opt("config", "", "TOML config file ([model] center/normalize defaults)")
                .opt("out", "scores.csv", "output CSV path")
                .opt("threads", "0", "scoring worker threads (0 = all cores)")
                .opt("chunk-docs", "2048", "documents per streamed chunk")
                .opt("top", "1", "top-k topic assignment depth")
                .switch("no-center", "do not subtract training means")
                .switch("normalize", "divide loadings by training std deviations")
                .switch("allow-vocab-mismatch", "score even if the vocab hash differs")
                .switch("progress", "print live scoring progress to stderr"),
        )
        .command(
            CommandSpec::new("serve", "serve models over HTTP: /v1 API, hot reload, /metrics")
                .req("model", "default model artifact (.lspm), hot-reloaded when rewritten")
                .opt("config", "", "TOML config file ([serve]/[model] sections)")
                .opt("models", "", "extra registry entries: name=path[,name=path...]")
                .opt("addr", "", "bind address (empty = config value, default 127.0.0.1:7878)")
                .opt("pool", "", "event-loop worker threads (empty = config value)")
                .opt("timeout-secs", "", "idle-connection timeout secs, 0 = none (empty = config)")
                .opt("queue-depth", "", "accept-queue cap before 503 shedding (empty = config)")
                .opt("max-conns", "", "open-connection cap before 503 shedding (empty = config)")
                .opt("reload-poll-ms", "", "artifact watch interval ms, 0 = off (empty = config)")
                .switch("no-center", "do not subtract training means")
                .switch("normalize", "divide loadings by training std deviations"),
        )
        .command(
            with_training_flags(CommandSpec::new(
                "watch",
                "daemon: poll a growing docword corpus, append + refit, rewrite the artifact",
            ))
            .req("model-out", "LSPM artifact kept fresh (point `lsspca serve --model` here)")
            .opt("poll-ms", "", "corpus poll interval ms (empty = config value, default 1000)")
            .opt("max-refits", "0", "stop after N refits, counting the initial fit (0 = run forever)"),
        )
        .command(
            CommandSpec::new("dlq", "inspect or retry a dead-letter queue (deadletter.jsonl)")
                .req("path", "deadletter.jsonl written by a pass with max_bad_records > 0")
                .opt("list", "10", "print the first N quarantined records (0 = none)")
                .opt("vocab-size", "0", "validate retried word ids against this vocab size (0 = skip)")
                .switch("retry", "re-parse quarantined lines and report which are recoverable"),
        )
        .command(
            CommandSpec::new("worker", "distributed-pass worker (spawned by the coordinator)")
                .req("manifest", "dist job manifest (distjob_*.lsjs) written by the coordinator")
                .req("shard", "shard index from the manifest's shard table"),
        )
        .command(
            CommandSpec::new("gen", "generate a synthetic corpus to disk (UCI docword format)")
                .req("out", "output path (.gz for gzip)")
                .opt("preset", "nytimes", "nytimes|pubmed")
                .opt("docs", "0", "documents (0 = preset default)")
                .opt("vocab", "0", "vocabulary (0 = preset default)")
                .opt("seed", "20111212", "seed"),
        )
        .command(
            CommandSpec::new("variances", "streamed variance profile of a docword file (Fig 2)")
                .req("input", "docword file")
                .opt("workers", "2", "worker threads")
                .opt("top", "20", "print the top-k features"),
        )
        .command(
            CommandSpec::new("solve", "run BCA on a synthetic covariance model")
                .opt("n", "100", "problem size")
                .opt("m", "300", "samples for the covariance model")
                .opt("model", "spiked", "spiked|gaussian")
                .opt("card", "10", "spike cardinality (spiked model)")
                .opt("lambda", "-1", "penalty λ (-1 = auto from variances)")
                .opt("sweeps", "20", "max BCA sweeps")
                .opt("seed", "7", "model seed"),
        )
        .command(
            CommandSpec::new("artifacts", "load and list AOT artifacts through PJRT")
                .opt("dir", "artifacts", "artifact directory"),
        )
        .command(
            CommandSpec::new(
                "bench",
                "hot-path benchmarks (qp_micro + fig1_speed scenarios) → BENCH_bca.json",
            )
            .opt("n", "512", "BCA problem size for the headline scenario")
            .opt("sweeps", "5", "fixed BCA sweeps K")
            .opt("threads", "4", "worker threads for the λ-search scaling scenario")
            .opt("out", "BENCH_bca.json", "output JSON path")
            .opt("covop-out", "BENCH_covop.json", "covariance-operator race output JSON path")
            .opt("score-out", "BENCH_score.json", "batch-scoring throughput output JSON path")
            .opt("oocore-out", "BENCH_oocore.json", "out-of-core backend race output JSON path")
            .opt("kernels", "", "SIMD kernel tier: auto|scalar|avx2|neon (empty = env or auto)")
            .opt("kernels-out", "BENCH_kernels.json", "kernel micro-bench output JSON path")
            .opt("serve-out", "BENCH_serve.json", "serving-latency output JSON path")
            .opt("incr-out", "BENCH_incr.json", "incremental-append output JSON path")
            .opt("compare", "", "baseline BENCH_bca.json: exit nonzero on gate regression")
            .opt("max-regress", "0.25", "allowed fractional slowdown of gate medians")
            .switch("quick", "smaller sizes / fewer repetitions"),
        )
}

/// Assemble a pipeline config from the flags shared by `run` and
/// `export`: config-file values first, flags override.
fn pipeline_config_from_args(args: &Args) -> Result<PipelineConfig, LsspcaError> {
    let mut cfg = if args.str("config").is_empty() {
        PipelineConfig::default()
    } else {
        PipelineConfig::load(Path::new(&args.str("config")))?
    };
    if !args.str("input").is_empty() {
        cfg.input = args.str("input");
    }
    cfg.synth_preset = args.str("preset");
    if args.usize("docs")? > 0 {
        cfg.synth_docs = args.usize("docs")?;
    }
    if args.usize("vocab")? > 0 {
        cfg.synth_vocab = args.usize("vocab")?;
    }
    cfg.seed = args.u64("seed")?;
    cfg.num_pcs = args.usize("pcs")?;
    cfg.target_card = args.usize("target-card")?;
    cfg.max_reduced = args.usize("max-reduced")?;
    cfg.workers = args.usize("workers")?;
    // Empty default keeps the config file's solver.threads; an explicit
    // flag (including 0 = all cores) overrides it.
    if !args.str("threads").is_empty() {
        cfg.threads = args.usize("threads")?;
    }
    cfg.engine = args.str("engine");
    if !args.str("kernels").is_empty() {
        cfg.kernels = args.str("kernels");
    }
    cfg.fast_math = cfg.fast_math || args.switch("fast-math");
    if !args.str("cov-backend").is_empty() {
        cfg.cov_backend = args.str("cov-backend");
    }
    if !args.str("row-cache-mb").is_empty() {
        cfg.row_cache_mb = args.usize("row-cache-mb")?;
    }
    if !args.str("memory-budget-mb").is_empty() {
        cfg.memory_budget_mb = args.usize("memory-budget-mb")?;
    }
    if !args.str("shard-mb").is_empty() {
        cfg.shard_mb = args.usize("shard-mb")?;
    }
    cfg.artifacts_dir = args.str("artifacts");
    if !args.str("cache-dir").is_empty() {
        cfg.cache_dir = args.str("cache-dir");
    }
    if !args.str("save-model").is_empty() {
        cfg.save_model = args.str("save-model");
    }
    if !args.str("max-bad-records").is_empty() {
        cfg.robust_max_bad_records = args.u64("max-bad-records")?;
    }
    if !args.str("dead-letter-path").is_empty() {
        cfg.robust_dead_letter_path = args.str("dead-letter-path");
    }
    if !args.str("retry-attempts").is_empty() {
        cfg.robust_retry_attempts = args.usize("retry-attempts")?;
    }
    if !args.str("retry-base-ms").is_empty() {
        cfg.robust_retry_base_ms = args.u64("retry-base-ms")?;
    }
    match args.str("job-state").as_str() {
        "" => {}
        "on" | "true" | "1" => cfg.robust_job_state = true,
        "off" | "false" | "0" => cfg.robust_job_state = false,
        other => {
            return Err(LsspcaError::config(format!(
                "--job-state must be on or off (got '{other}')"
            )))
        }
    }
    if !args.str("job-state-chunks").is_empty() {
        cfg.robust_job_state_chunks = args.usize("job-state-chunks")?;
    }
    if !args.str("faults").is_empty() {
        cfg.robust_faults = args.str("faults");
    }
    if !args.str("dist-workers").is_empty() {
        cfg.dist_workers = args.usize("dist-workers")?;
    }
    if !args.str("dist-shard-docs").is_empty() {
        cfg.dist_shard_docs = args.u64("dist-shard-docs")?;
    }
    cfg.certify = cfg.certify || args.switch("certify");
    Ok(cfg)
}

/// Select the SIMD dispatch tier and fast-math opt-in from the
/// `[compute]` settings (config file; `--kernels` / `--fast-math`
/// override). Returns the resolved tier so callers can report it.
fn apply_compute(cfg: &PipelineConfig) -> Result<lsspca::kernels::Tier, LsspcaError> {
    let tier = lsspca::kernels::apply_settings(&cfg.kernels, cfg.fast_math)?;
    lsspca::debug!("compute: kernel dispatch tier {} (fast_math {})", tier.name(), cfg.fast_math);
    Ok(tier)
}

fn cmd_run(args: &Args) -> Result<(), LsspcaError> {
    let cfg = pipeline_config_from_args(args)?;
    cfg.validate()?;
    apply_compute(&cfg)?;

    let mut pipeline = Pipeline::new(cfg);
    if args.switch("progress") {
        pipeline = pipeline.with_observer(Arc::new(StderrProgress::new()));
    }
    let report = pipeline.run()?;
    println!("\n# {} — sparse PCA report", report.corpus_name);
    println!(
        "docs={} vocab={} nnz={} | reduced n̂={} ({}x reduction, λ̂={:.4e}{})",
        report.num_docs,
        report.vocab_size,
        report.nnz,
        report.reduced_size,
        report.reduction_factor as u64,
        report.elim_lambda,
        if report.elim_capped { ", capped" } else { "" }
    );
    println!("\n{}", report.topic_table);
    for (k, c) in report.components.iter().enumerate() {
        let cert = c
            .certificate_gap
            .map(|g| format!(" gap≤{g:.2e}"))
            .unwrap_or_default();
        println!(
            "PC{}: card={} λ={:.4} φ={:.4} explained={:.4} ({:.2}s){cert}",
            k + 1,
            c.pc.cardinality(),
            c.lambda,
            c.phi,
            c.explained_variance,
            c.seconds
        );
    }
    println!("\ntotal: {:.2}s", report.total_seconds);
    if args.switch("profile") {
        println!("\n{}", report.profile);
    }
    Ok(())
}

fn cmd_export(args: &Args) -> Result<(), LsspcaError> {
    let mut cfg = pipeline_config_from_args(args)?;
    if !args.str("model-out").is_empty() {
        cfg.save_model = args.str("model-out");
    }
    if cfg.save_model.is_empty() {
        cfg.save_model = "model.lspm".into();
    }
    cfg.validate()?;
    apply_compute(&cfg)?;
    let out = cfg.save_model.clone();
    let report = Pipeline::new(cfg).run()?;
    println!("{}", report.model.summary());
    println!("wrote {out}");
    Ok(())
}

fn cmd_score(args: &Args) -> Result<(), LsspcaError> {
    let model = Model::load(Path::new(&args.str("model")))?;
    let input = PathBuf::from(args.str("input"));
    // Vocabulary identity check: when the input ships a vocab companion
    // file, its hash must match the training vocabulary's — scoring
    // against re-indexed words silently permutes every topic otherwise.
    let vocab_path = input.with_extension("vocab");
    if vocab_path.exists() && model.vocab_hash != 0 {
        let v = Vocab::load(&vocab_path)?;
        let h = lsspca::model::vocab_hash(&v);
        if h != model.vocab_hash && !args.switch("allow-vocab-mismatch") {
            return Err(LsspcaError::config(format!(
                "vocabulary mismatch: {} hashes to {h:016x}, model was trained on {:016x} \
                 (--allow-vocab-mismatch to override)",
                vocab_path.display(),
                model.vocab_hash
            )));
        }
    }
    // [model] center/normalize give the defaults; switches override.
    let cfg = if args.str("config").is_empty() {
        PipelineConfig::default()
    } else {
        PipelineConfig::load(Path::new(&args.str("config")))?
    };
    apply_compute(&cfg)?;
    let sopts = ScoreOptions {
        center: cfg.score_center && !args.switch("no-center"),
        normalize: cfg.score_normalize || args.switch("normalize"),
    };
    let scorer = Scorer::new(&model, sopts)?;
    let bopts = BatchOptions {
        threads: args.usize("threads")?,
        chunk_docs: args.usize("chunk-docs")?,
        top: args.usize("top")?,
    };
    let out = PathBuf::from(args.str("out"));
    let stderr_progress;
    let progress: &dyn lsspca::session::Progress = if args.switch("progress") {
        stderr_progress = StderrProgress::new();
        &stderr_progress
    } else {
        &NoopProgress
    };
    let stats = score_file_observed(&input, &scorer, bopts, &out, progress)?;
    println!(
        "scored {} docs ({} nnz) onto {} PCs in {:.2}s — {:.0} docs/s → {}",
        stats.docs,
        stats.nnz,
        scorer.num_pcs(),
        stats.seconds,
        stats.docs_per_sec(),
        out.display()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), LsspcaError> {
    let cfg = if args.str("config").is_empty() {
        PipelineConfig::default()
    } else {
        PipelineConfig::load(Path::new(&args.str("config")))?
    };
    apply_compute(&cfg)?;
    let mut b = ServerBuilder::from_config(&cfg)?.score_options(ScoreOptions {
        center: cfg.score_center && !args.switch("no-center"),
        normalize: cfg.score_normalize || args.switch("normalize"),
    });
    if !args.str("addr").is_empty() {
        b = b.addr(args.str("addr"));
    }
    if !args.str("pool").is_empty() {
        b = b.workers(args.usize("pool")?);
    }
    if !args.str("timeout-secs").is_empty() {
        b = b.timeout_secs(args.u64("timeout-secs")?);
    }
    if !args.str("queue-depth").is_empty() {
        b = b.queue_depth(args.usize("queue-depth")?);
    }
    if !args.str("max-conns").is_empty() {
        b = b.max_conns(args.usize("max-conns")?);
    }
    if !args.str("reload-poll-ms").is_empty() {
        b = b.reload_poll_ms(args.u64("reload-poll-ms")?);
    }
    for entry in args.str("models").split(',').filter(|s| !s.is_empty()) {
        let Some((name, path)) = entry.split_once('=') else {
            return Err(LsspcaError::config(format!(
                "--models entry '{entry}' must be 'name=path'"
            )));
        };
        b = b.register(name, path);
    }
    // The --model flag is the default model, path-backed so a rewritten
    // artifact hot-reloads without a restart.
    let server =
        b.register("default", args.str("model")).default_model("default").build()?;
    println!(
        "serving on http://{} — GET /v1/models /v1/healthz /v1/metrics, \
         POST /v1/models/{{name}}/score (legacy /score /topics /healthz deprecated)",
        server.local_addr()
    );
    server.run()
}

fn cmd_watch(args: &Args) -> Result<(), LsspcaError> {
    let cfg = pipeline_config_from_args(args)?;
    cfg.validate()?;
    apply_compute(&cfg)?;
    let poll_ms = if args.str("poll-ms").is_empty() {
        cfg.incr_watch_poll_ms
    } else {
        args.u64("poll-ms")?
    };
    let opts = lsspca::incr::watch::WatchOptions {
        poll: std::time::Duration::from_millis(poll_ms),
        max_refits: args.u64("max-refits")?,
        model_out: PathBuf::from(args.str("model-out")),
    };
    println!(
        "watching {} every {poll_ms} ms → {} (stop with ^C{})",
        cfg.input,
        opts.model_out.display(),
        if opts.max_refits > 0 {
            format!(", or after {} refits", opts.max_refits)
        } else {
            String::new()
        }
    );
    // No in-process stop signal: the daemon runs until --max-refits or
    // the process is killed. A kill mid-append is safe — the resumable
    // job state picks the fold back up bitwise on the next start.
    let shutdown = std::sync::atomic::AtomicBool::new(false);
    let report = lsspca::incr::watch::watch_corpus(&cfg, &opts, &shutdown)?;
    println!(
        "watch done: {} appends, {} refits, {} drift re-eliminations",
        report.appends, report.refits, report.drifts
    );
    Ok(())
}

/// Can a quarantined line now be parsed as a valid docword triple? Mirrors
/// the reader's checks (three base-10 fields, ids ≥ 1, count ≥ 1, word ≤ W
/// when a vocab size is given) — monotonicity is a *stream* property the
/// single line cannot establish, so `dlq --retry` reports those lines as
/// parseable and leaves the ordering decision to a re-run.
fn dlq_line_recoverable(line: &str, vocab_size: usize) -> bool {
    let mut parts = line.split_whitespace();
    let (Some(d), Some(w), Some(c)) = (parts.next(), parts.next(), parts.next()) else {
        return false;
    };
    if parts.next().is_some() {
        return false;
    }
    let (Ok(doc), Ok(word), Ok(count)) =
        (d.parse::<usize>(), w.parse::<usize>(), c.parse::<u64>())
    else {
        return false;
    };
    doc >= 1 && word >= 1 && count >= 1 && (vocab_size == 0 || word <= vocab_size)
}

fn cmd_dlq(args: &Args) -> Result<(), LsspcaError> {
    use lsspca::deadletter::read_records;
    let path = PathBuf::from(args.str("path"));
    let records = read_records(&path)?;
    if records.is_empty() {
        println!("{}: empty dead-letter queue", path.display());
        return Ok(());
    }
    // Per-reason histogram plus the checksum health of the file itself.
    let mut by_reason: Vec<(String, u64)> = Vec::new();
    let mut bad_crc = 0u64;
    for r in &records {
        if !r.crc_ok {
            bad_crc += 1;
        }
        match by_reason.iter_mut().find(|(k, _)| *k == r.reason_str) {
            Some((_, n)) => *n += 1,
            None => by_reason.push((r.reason_str.clone(), 1)),
        }
    }
    println!("{}: {} quarantined records", path.display(), records.len());
    for (reason, n) in &by_reason {
        println!("  {reason:<20} {n}");
    }
    if bad_crc > 0 {
        println!("  WARNING: {bad_crc} records fail their crc (corrupted queue file)");
    }
    let list = args.usize("list")?;
    for r in records.iter().take(list) {
        println!(
            "  offset={} reason={} crc={} line={:?} — {}",
            r.offset,
            r.reason_str,
            if r.crc_ok { "ok" } else { "BAD" },
            r.line,
            r.detail
        );
    }
    if records.len() > list && list > 0 {
        println!("  … {} more (raise --list to see them)", records.len() - list);
    }
    if args.switch("retry") {
        let vocab_size = args.usize("vocab-size")?;
        let (mut recoverable, mut dead) = (0u64, 0u64);
        for r in &records {
            if dlq_line_recoverable(&r.line, vocab_size) {
                recoverable += 1;
            } else {
                dead += 1;
            }
        }
        println!(
            "retry: {recoverable} recoverable / {dead} permanently malformed{}",
            if vocab_size == 0 { " (word-id range unchecked; pass --vocab-size)" } else { "" }
        );
        if dead > 0 {
            return Err(LsspcaError::corpus(format!(
                "{dead} quarantined records are not recoverable (see listing above)"
            )));
        }
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<(), LsspcaError> {
    let spec = CorpusSpec::preset(&args.str("preset"))
        .ok_or_else(|| LsspcaError::config("unknown preset"))?
        .scaled(args.usize("docs")?, args.usize("vocab")?);
    let corpus = SynthCorpus::new(spec, args.u64("seed")?);
    let out = PathBuf::from(args.str("out"));
    let t = lsspca::util::Timer::start();
    let hdr = corpus.write_docword(&out)?;
    println!(
        "wrote {}: D={} W={} NNZ={} in {:.1}s (+ vocab at {})",
        out.display(),
        hdr.num_docs,
        hdr.vocab_size,
        hdr.nnz,
        t.secs(),
        out.with_extension("vocab").display()
    );
    Ok(())
}

fn cmd_variances(args: &Args) -> Result<(), LsspcaError> {
    let input = PathBuf::from(args.str("input"));
    let opts = StreamOptions { workers: args.usize("workers")?, ..Default::default() };
    let (hdr, fv, stats) = variance_pass_file(&input, opts)?;
    let sorted = fv.sorted_variances();
    println!(
        "D={} W={} NNZ={} | pass took {:.2}s with {} workers",
        hdr.num_docs, hdr.vocab_size, hdr.nnz, stats.seconds, opts.workers
    );
    let pts: Vec<(f64, f64)> = sorted
        .iter()
        .enumerate()
        .filter(|&(_, &v)| v > 0.0)
        .map(|(i, &v)| ((i + 1) as f64, v))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("sorted word variances (cf. paper Fig 2)")
            .logx()
            .logy()
            .series("variance", '*', &pts)
            .render()
    );
    let vocab_path = input.with_extension("vocab");
    let vocab = if vocab_path.exists() { Vocab::load(&vocab_path)? } else { Vocab::default() };
    println!("top features by variance:");
    for (rank, (idx, var)) in fv.ranked().into_iter().take(args.usize("top")?).enumerate() {
        println!("  {:>3}. {:<20} {var:.4}", rank + 1, vocab.word(idx));
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<(), LsspcaError> {
    let n = args.usize("n")?;
    let m = args.usize("m")?;
    let mut rng = Rng::seed_from(args.u64("seed")?);
    let sigma = match args.str("model").as_str() {
        "spiked" => {
            lsspca::corpus::spiked_covariance(n, m, args.usize("card")?.min(n), 2.0, &mut rng)
        }
        "gaussian" => lsspca::corpus::gaussian_factor_cov(n, m, &mut rng),
        other => return Err(LsspcaError::config(format!("unknown model '{other}'"))),
    };
    let mut lambda = args.f64("lambda")?;
    if lambda < 0.0 {
        let diags: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        lambda = lsspca::elim::lambda_for_survivors(&diags, (2 * args.usize("card")?).max(10));
        println!("auto λ = {lambda:.4}");
    }
    let opts = BcaOptions { max_sweeps: args.usize("sweeps")?, ..Default::default() };
    let sol = bca::solve(&sigma, lambda, &opts);
    let pc = lsspca::solver::extract::leading_sparse_pc(&sol.z, 1e-4);
    println!(
        "φ={:.6} sweeps={} final_delta={:.2e} time={:.2}s",
        sol.phi, sol.sweeps, sol.final_delta, sol.seconds
    );
    println!("support ({}): {:?}", pc.cardinality(), pc.support);
    let series: Vec<(f64, f64)> = sol
        .history
        .iter()
        .map(|h| (h.seconds.max(1e-6), h.objective))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("objective vs time")
            .series("BCA", 'o', &series)
            .render()
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_artifacts(args: &Args) -> Result<(), LsspcaError> {
    let dir = PathBuf::from(args.str("dir"));
    let mut rt = lsspca::runtime::Runtime::new()
        .map_err(|e| LsspcaError::io(format!("{e:#}")))?;
    let names = rt.load_dir(&dir).map_err(|e| LsspcaError::io(format!("{e:#}")))?;
    println!("loaded {} artifacts from {}:", names.len(), dir.display());
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts(_args: &Args) -> Result<(), LsspcaError> {
    Err(LsspcaError::config(
        "this build has no XLA support (rebuild with --features xla)",
    ))
}

/// Time one closure: min wall-clock over `reps` runs (first run warms).
fn time_min<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = lsspca::util::Timer::start();
        lsspca::util::bench::black_box(f());
        best = best.min(t.secs());
    }
    best
}

/// Per-run wall-clock samples of one closure (for gate medians, which
/// want a robust central tendency rather than the optimistic min).
fn time_samples<T>(reps: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    (0..reps.max(1))
        .map(|_| {
            let t = lsspca::util::Timer::start();
            lsspca::util::bench::black_box(f());
            t.secs()
        })
        .collect()
}

fn median_secs(samples: &[f64]) -> f64 {
    lsspca::util::stats::Summary::of(samples).p50
}

/// Read exactly one HTTP/1.1 response from a keep-alive stream: headers
/// up to the blank line, then `Content-Length` body bytes. Returns the
/// status line. Byte-at-a-time header reads — responses here are a few
/// hundred bytes, so simplicity beats buffering.
fn read_bench_response(stream: &mut std::net::TcpStream) -> Result<String, String> {
    use std::io::Read;
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        match stream.read(&mut byte) {
            Ok(0) => return Err("connection closed mid-response".into()),
            Ok(_) => head.push(byte[0]),
            Err(e) => return Err(format!("reading response head: {e}")),
        }
        if head.len() > 64 * 1024 {
            return Err("response head too large".into());
        }
    }
    let head = String::from_utf8_lossy(&head).into_owned();
    let mut content_length = 0usize;
    for line in head.lines() {
        let lower = line.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length =
                v.trim().parse().map_err(|e| format!("bad content-length: {e}"))?;
        }
    }
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body).map_err(|e| format!("reading response body: {e}"))?;
    Ok(head.lines().next().unwrap_or_default().to_string())
}

/// The bench-regression gate: compare this run's scenario medians against
/// a committed baseline; any metric slower than `(1 + max_regress)×`
/// baseline fails the gate (CI exits nonzero). Baselines are only
/// comparable between runs of the same shape, so `quick`/`n` must match.
fn bench_compare_gate(
    baseline_path: &Path,
    current: &[(&str, f64)],
    quick: bool,
    n: usize,
    max_regress: f64,
) -> Result<(), LsspcaError> {
    use lsspca::util::bench::{metric, section};
    let text = std::fs::read_to_string(baseline_path)
        .map_err(|e| LsspcaError::io_at(baseline_path, format!("reading baseline: {e}")))?;
    let doc = Json::parse(&text).map_err(|e| {
        LsspcaError::config(format!("parsing baseline {}: {e}", baseline_path.display()))
    })?;
    let gate = doc.get("gate").ok_or_else(|| {
        LsspcaError::config(format!(
            "baseline {} has no \"gate\" object",
            baseline_path.display()
        ))
    })?;
    let base_quick = gate.get("quick").and_then(Json::as_bool).unwrap_or(false);
    let base_n = gate.get("n").and_then(Json::as_f64).unwrap_or(0.0) as usize;
    if base_quick != quick || base_n != n {
        return Err(LsspcaError::config(format!(
            "baseline gate shape mismatch: baseline quick={base_quick} n={base_n}, \
             this run quick={quick} n={n} — regenerate the baseline with matching flags"
        )));
    }
    section(&format!(
        "bench gate — vs {} (fail above {:.0}% slowdown)",
        baseline_path.display(),
        max_regress * 100.0
    ));
    let mut failures = Vec::new();
    for &(name, cur) in current {
        let base = gate
            .get(name)
            .and_then(Json::as_f64)
            .ok_or_else(|| LsspcaError::config(format!("baseline gate is missing \"{name}\"")))?;
        if !base.is_finite() || base <= 0.0 {
            return Err(LsspcaError::config(format!(
                "baseline gate \"{name}\" must be > 0 (got {base})"
            )));
        }
        let ratio = cur / base;
        let ok = ratio <= 1.0 + max_regress;
        metric(
            &format!("gate.{name}.ratio"),
            format!("{ratio:.3} ({})", if ok { "ok" } else { "REGRESSION" }),
        );
        if !ok {
            failures.push(format!(
                "{name}: {cur:.6}s vs baseline {base:.6}s ({ratio:.2}x > {:.2}x allowed)",
                1.0 + max_regress
            ));
        }
    }
    if failures.is_empty() {
        println!("bench gate: ok");
        Ok(())
    } else {
        Err(LsspcaError::numeric(format!(
            "bench gate failed:\n  {}",
            failures.join("\n  ")
        )))
    }
}

fn cmd_bench(args: &Args) -> Result<(), LsspcaError> {
    use lsspca::solver::lambda::{search, LambdaSearchOptions};
    use lsspca::solver::qp::{self, QpOptions};
    use lsspca::util::bench::{metric, section};

    let quick = args.switch("quick");
    let n = if quick { args.usize("n")?.min(128) } else { args.usize("n")? };
    let sweeps = args.usize("sweeps")?;
    let threads = args.usize("threads")?.max(1);
    let reps = if quick { 1 } else { 2 };
    let tier = if args.str("kernels").is_empty() {
        lsspca::kernels::active()
    } else {
        lsspca::kernels::apply_settings(&args.str("kernels"), false)?
    };
    metric("kernels.dispatch_tier", tier.name().to_string());
    let mut rng = Rng::seed_from(20111212);
    let mut json = String::from("{\n");

    // --- qp_micro: cold vs warm-started/active-set box-QP ----------------
    section("qp_micro — box-QP coordinate descent, cold vs warm");
    json.push_str("  \"qp_micro\": [\n");
    let qp_sizes: &[usize] = if quick { &[64, 128] } else { &[64, 128, 256, 512] };
    for (idx, &qn) in qp_sizes.iter().enumerate() {
        let y = SymMat::random_psd(qn, qn / 2 + 4, 0.05, &mut rng);
        let s = rng.gauss_vec(qn);
        let lambda = 0.3;
        let opts = QpOptions::default();
        let radius = vec![lambda; qn];
        let cold = time_min(reps + 1, || {
            let mut u = Vec::new();
            let mut w = Vec::new();
            qp::solve_masked(&y, &s, &radius, None, opts, &mut u, &mut w).r_squared
        });
        // warm re-solve, as the BCA outer loop sees it from sweep 2 on
        let prev = qp::solve(&y, &s, lambda, opts).u;
        let warm = time_min(reps + 1, || {
            let mut u = Vec::new();
            let mut w = Vec::new();
            let mut active = Vec::new();
            qp::solve_masked_warm(
                &y, &s, &radius, None, opts, Some(&prev), &mut u, &mut w, &mut active,
            )
            .r_squared
        });
        metric(&format!("qp.n{qn}.cold_secs"), format!("{cold:.6}"));
        metric(&format!("qp.n{qn}.warm_secs"), format!("{warm:.6}"));
        metric(&format!("qp.n{qn}.speedup"), format!("{:.2}", cold / warm.max(1e-12)));
        json.push_str(&format!(
            "    {{\"n\": {qn}, \"cold_secs\": {cold:.6}, \"warm_secs\": {warm:.6}, \
             \"speedup\": {:.3}}}{}\n",
            cold / warm.max(1e-12),
            if idx + 1 == qp_sizes.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");

    // --- qp_micro gate median: repeated cold solves at the largest size ---
    let gate_reps = if quick { 5 } else { 7 };
    let gate_qn = *qp_sizes.last().unwrap();
    let qp_gate_median = {
        let y = SymMat::random_psd(gate_qn, gate_qn / 2 + 4, 0.05, &mut rng);
        let s = rng.gauss_vec(gate_qn);
        let radius = vec![0.3; gate_qn];
        let opts = QpOptions::default();
        let samples = time_samples(gate_reps, || {
            let mut u = Vec::new();
            let mut w = Vec::new();
            qp::solve_masked(&y, &s, &radius, None, opts, &mut u, &mut w).r_squared
        });
        median_secs(&samples)
    };
    metric("gate.qp_micro_median_secs", format!("{qp_gate_median:.6}"));

    // --- fig1_speed headline: BCA at n, K sweeps, cold/serial vs hot ------
    // Paper regime: a strong cardinality-5 spike. BCA then concentrates X,
    // the column QPs become ill-conditioned, and cold starts pay heavily —
    // exactly the case the workspace exists for.
    section(&format!("fig1_speed — BCA n={n}, K={sweeps}: reference vs workspace"));
    let sigma = lsspca::corpus::spiked_covariance(n, 2 * n, 5, 10.0, &mut rng);
    let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, 3 * n / 4);
    let opts = BcaOptions {
        track_history: false,
        ..BcaOptions::fixed_sweeps(sweeps)
    };
    // One timed reference run (solves are seconds-scale at n = 512); the
    // workspace side samples a few runs so the gate gets a median. φ comes
    // from the timed runs, so equivalence is measured on what was timed.
    let t = lsspca::util::Timer::start();
    let phi_ref = bca::solve_reference(&sigma, lambda, &opts).phi;
    let ref_secs = t.secs();
    let ws_reps = if quick { 5 } else { 3 };
    let mut phi_ws = 0.0;
    let ws_samples = time_samples(ws_reps, || {
        phi_ws = bca::solve(&sigma, lambda, &opts).phi;
    });
    let ws_secs = ws_samples.iter().copied().fold(f64::INFINITY, f64::min);
    let fig1_gate_median = median_secs(&ws_samples);
    let bca_speedup = ref_secs / ws_secs.max(1e-12);
    metric("bca.reference_secs", format!("{ref_secs:.4}"));
    metric("bca.workspace_secs", format!("{ws_secs:.4}"));
    metric("bca.speedup", format!("{bca_speedup:.2}"));
    metric("bca.phi_abs_diff", format!("{:.3e}", (phi_ref - phi_ws).abs()));
    metric("gate.fig1_speed_median_secs", format!("{fig1_gate_median:.6}"));
    json.push_str(&format!(
        "  \"bca_n{n}\": {{\"n\": {n}, \"sweeps\": {sweeps}, \"reference_secs\": {ref_secs:.6}, \
         \"workspace_secs\": {ws_secs:.6}, \"speedup\": {bca_speedup:.3}, \
         \"phi_abs_diff\": {:.3e}}},\n",
        (phi_ref - phi_ws).abs()
    ));

    // --- session_refit: warm Session::fit at a new λ vs cold one-shot -----
    // The staged-session API's headline number: once a session has
    // streamed/eliminated/reduced the corpus, a re-fit at a new (λ, K)
    // touches only the reduced operator. The gate tracks the warm
    // re-fit median so a regression in the fit hot path (or an
    // accidental stage re-run) fails CI.
    section("session — warm re-fit at a new λ vs cold one-shot run");
    let sr_docs = if quick { 600 } else { 2000 };
    let sr_cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: sr_docs,
        synth_vocab: 3000,
        workers: 2,
        chunk_docs: 256,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 64,
        bca_sweeps: sweeps,
        ..Default::default()
    };
    let t = lsspca::util::Timer::start();
    let cold_report = Pipeline::new(sr_cfg.clone()).run()?;
    let cold_secs = t.secs();
    // a λ the cold run never solved at: between the first two PCs' λs
    let lam_new = 0.5 * (cold_report.components[0].lambda + cold_report.components[1].lambda);
    let mut warm = Session::from_config(sr_cfg.clone())?;
    warm.reduce()?;
    let sr_reps = if quick { 5 } else { 7 };
    let warm_samples = time_samples(sr_reps, || {
        warm.fit(LambdaSpec::Fixed(lam_new), 2).expect("warm re-fit")
    });
    let warm_min = warm_samples.iter().copied().fold(f64::INFINITY, f64::min);
    let session_refit_median = median_secs(&warm_samples);
    metric("session.cold_oneshot_secs", format!("{cold_secs:.4}"));
    metric("session.warm_refit_secs", format!("{warm_min:.6}"));
    metric(
        "session.refit_speedup",
        format!("{:.1}", cold_secs / warm_min.max(1e-12)),
    );
    metric("gate.session_refit_median_secs", format!("{session_refit_median:.6}"));
    json.push_str(&format!(
        "  \"session_refit\": {{\"docs\": {sr_docs}, \"pcs\": 2, \
         \"cold_oneshot_secs\": {cold_secs:.6}, \"warm_refit_secs\": {warm_min:.6}, \
         \"warm_refit_median_secs\": {session_refit_median:.6}, \
         \"speedup\": {:.3}}},\n",
        cold_secs / warm_min.max(1e-12)
    ));

    // --- session_append: fold a 1% segment + warm refit vs cold re-run ----
    // The incremental subsystem's headline number: once a session is fit,
    // folding a 1% appended segment and warm-refitting must cost a small
    // fraction of the cold one-shot (the appended docs are the only
    // corpus bytes touched). The gate tracks the append+refit median.
    use lsspca::incr::LimitSource;
    use lsspca::stream::SynthSource as BenchSynthSource;

    section("session — incremental 1% append + warm refit vs cold one-shot");
    let sa_docs = sr_docs;
    let sa_grow = (sa_docs / 100).max(8);
    let sa_reps = if quick { 3 } else { 5 };
    let mut inc = Session::from_config(sr_cfg.clone())?;
    let t = lsspca::util::Timer::start();
    inc.refit_incremental()?;
    let sa_bootstrap_secs = t.secs();
    // One generator big enough for every segment; position-seeded docs
    // mean the suffix is exactly what a larger corpus would contain.
    let sa_grown = SynthCorpus::new(
        CorpusSpec::nytimes().scaled(sa_docs + sa_reps * sa_grow, sr_cfg.synth_vocab),
        sr_cfg.seed,
    );
    let mut sa_seg = 0u64;
    let append_samples = time_samples(sa_reps, || {
        let start = sa_docs as u64 + sa_seg * sa_grow as u64;
        let mut src = LimitSource::new(
            BenchSynthSource::starting_at(&sa_grown, start),
            sa_grow as u64,
        );
        inc.append(&mut src, &format!("bench-append:{sa_seg}")).expect("append");
        inc.refit_incremental().expect("incremental refit");
        sa_seg += 1;
    });
    let append_min = append_samples.iter().copied().fold(f64::INFINITY, f64::min);
    let session_append_median = median_secs(&append_samples);
    let sa_speedup = cold_secs / append_min.max(1e-12);
    metric("session.append_bootstrap_secs", format!("{sa_bootstrap_secs:.4}"));
    metric("session.append_segment_docs", format!("{sa_grow}"));
    metric("session.append_refit_secs", format!("{append_min:.6}"));
    metric("session.append_speedup_vs_cold", format!("{sa_speedup:.1}"));
    metric("gate.session_append_median_secs", format!("{session_append_median:.6}"));
    let ij = format!(
        "{{\n  \"session_append\": {{\"base_docs\": {sa_docs}, \"segment_docs\": {sa_grow}, \
         \"segments\": {sa_reps}, \"bootstrap_secs\": {sa_bootstrap_secs:.6}, \
         \"append_refit_secs\": {append_min:.6}, \
         \"append_refit_median_secs\": {session_append_median:.6}, \
         \"cold_oneshot_secs\": {cold_secs:.6}, \"speedup\": {sa_speedup:.3}}}\n}}\n"
    );
    let incr_out = PathBuf::from(args.str("incr-out"));
    std::fs::write(&incr_out, &ij)
        .map_err(|e| LsspcaError::io_at(&incr_out, format!("writing bench json: {e}")))?;
    println!("wrote {}", incr_out.display());
    json.push_str(&format!(
        "  \"session_append\": {{\"base_docs\": {sa_docs}, \"segment_docs\": {sa_grow}, \
         \"append_refit_median_secs\": {session_append_median:.6}, \
         \"speedup_vs_cold\": {sa_speedup:.3}}},\n"
    ));

    // --- oocore: disk-backed covariance vs in-memory gram ------------------
    // Runs before the gate object is assembled because the disk matvec
    // median is one of the gated metrics.
    // (CovOp / GramCov come from the covop import further down — `use`
    // items are in scope for the whole function block.)
    use lsspca::cov_disk::DiskGramCov;
    use lsspca::data::shardcache::{self, ShardCacheKey};

    section("oocore — disk-backed covariance: matvec + λ-search vs in-memory gram");
    let onhat = if quick { 256 } else { 1024 };
    let odocs = 4 * onhat;
    let ocorpus =
        SynthCorpus::new(CorpusSpec::nytimes().scaled(odocs, onhat), 20111214);
    let ocsr = ocorpus.to_csr();
    let odir = std::env::temp_dir().join(format!("lsspca_bench_oocore_{}", std::process::id()));
    let okey = ShardCacheKey { corpus_digest: 0xbe0c, elim_digest: 0x0c0e };
    let t = lsspca::util::Timer::start();
    let oman = shardcache::write(&odir, &okey, &ocsr, odocs as u64, 256 * 1024)?;
    let shard_write_secs = t.secs();
    let ogram = GramCov::new(ocsr, odocs as u64, 16);
    let ox: Vec<f64> = (0..onhat).map(|_| rng.gauss()).collect();
    let (mut oyg, mut oyd) = (vec![0.0; onhat], vec![0.0; onhat]);
    let mv_gram = time_min(reps + 1, || ogram.matvec(&ox, &mut oyg));
    let odisk = DiskGramCov::new(&odir, oman.clone(), 16, threads);
    let mv_samples = time_samples(if quick { 5 } else { 7 }, || odisk.matvec(&ox, &mut oyd));
    let mv_disk = mv_samples.iter().copied().fold(f64::INFINITY, f64::min);
    let oocore_gate_median = median_secs(&mv_samples);
    let mv_bitwise = oyg.iter().zip(&oyd).all(|(a, b)| a.to_bits() == b.to_bits());
    metric("oocore.shards", format!("{}", oman.shards.len()));
    metric("oocore.shard_write_secs", format!("{shard_write_secs:.4}"));
    metric("oocore.matvec_gram_secs", format!("{mv_gram:.6}"));
    metric("oocore.matvec_disk_secs", format!("{mv_disk:.6}"));
    metric("oocore.matvec_bitwise_identical", format!("{mv_bitwise}"));
    metric("gate.oocore_disk_matvec_median_secs", format!("{oocore_gate_median:.6}"));
    // Σ-row gathers, cold (stream every shard) vs warm (row-cache hit).
    let osample: Vec<usize> = (0..16).map(|k| (k * onhat / 16) % onhat).collect();
    let mut obuf = vec![0.0; onhat];
    let t = lsspca::util::Timer::start();
    for &j in &osample {
        odisk.row_into(j, &mut obuf);
    }
    let rg_disk_cold = t.secs();
    let rg_disk_warm = time_min(reps + 1, || {
        for &j in &osample {
            odisk.row_into(j, &mut obuf);
        }
    });
    metric("oocore.rowgather16_disk_cold_secs", format!("{rg_disk_cold:.6}"));
    metric("oocore.rowgather16_disk_warm_secs", format!("{rg_disk_warm:.6}"));
    let mut oj = String::from("{\n");
    oj.push_str(&format!(
        "  \"matvec\": {{\"nhat\": {onhat}, \"docs\": {odocs}, \"shards\": {}, \
         \"shard_write_secs\": {shard_write_secs:.6}, \"gram_secs\": {mv_gram:.6}, \
         \"disk_secs\": {mv_disk:.6}, \"disk_median_secs\": {oocore_gate_median:.6}, \
         \"bitwise_identical\": {mv_bitwise}, \
         \"rowgather16_cold_secs\": {rg_disk_cold:.6}, \
         \"rowgather16_warm_secs\": {rg_disk_warm:.6}}},\n",
        oman.shards.len()
    ));
    // End-to-end λ-search throughput at several row-cache budgets: the
    // whole cardinality search (per-λ elimination masks on) on the disk
    // operator, against the in-memory gram reference.
    let mk_oocore_opts = || LambdaSearchOptions {
        target_card: 8,
        slack: 2,
        max_evals: 4,
        per_lambda_elim: true,
        threads,
        bca: BcaOptions { max_sweeps: sweeps, track_history: false, ..Default::default() },
        ..Default::default()
    };
    let t = lsspca::util::Timer::start();
    let gram_lambda = search(&ogram, &mk_oocore_opts()).lambda;
    let gram_search_secs = t.secs();
    oj.push_str(&format!(
        "  \"lambda_search\": {{\"gram_secs\": {gram_search_secs:.6}, \"budgets\": [\n"
    ));
    metric("oocore.lambda_search.gram_secs", format!("{gram_search_secs:.4}"));
    let budget_arms: &[usize] = if quick { &[0, 8] } else { &[4, 32] };
    for (idx, &cache_mb) in budget_arms.iter().enumerate() {
        let arm = DiskGramCov::new(&odir, oman.clone(), cache_mb, threads);
        let t = lsspca::util::Timer::start();
        let res = search(&arm, &mk_oocore_opts());
        let secs = t.secs();
        let identical = res.lambda == gram_lambda;
        metric(
            &format!("oocore.lambda_search.disk_cache{cache_mb}mb_secs"),
            format!("{secs:.4} (identical_result {identical})"),
        );
        oj.push_str(&format!(
            "    {{\"row_cache_mb\": {cache_mb}, \"secs\": {secs:.6}, \
             \"identical_result\": {identical}}}{}\n",
            if idx + 1 == budget_arms.len() { "" } else { "," }
        ));
    }
    oj.push_str("  ]}\n}\n");
    std::fs::remove_dir_all(&odir).ok();

    // --- kernels: dispatched dot + sparse Gram matvec micro-benches -------
    // Times the public dispatched kernels (whatever tier is active) and a
    // forced-scalar arm of the same workload; gate medians track the
    // active-tier numbers. Tier switches are bitwise-invisible (see
    // `lsspca::kernels`), so forcing scalar mid-bench is safe.
    section(&format!("kernels — dot/spmv micro-benches (dispatch tier: {})", tier.name()));
    let kn = if quick { 4096 } else { 16384 };
    let ka: Vec<f64> = (0..kn).map(|_| rng.gauss()).collect();
    let kb: Vec<f64> = (0..kn).map(|_| rng.gauss()).collect();
    let k_reps = if quick { 9 } else { 15 };
    // Batch many kernel calls per sample so timer resolution is moot.
    let dot_workload = |acc: &mut f64| {
        for _ in 0..256 {
            *acc += lsspca::kernels::dot(&ka, &kb);
        }
    };
    let kd_samples = time_samples(k_reps, || {
        let mut acc = 0.0;
        dot_workload(&mut acc);
        acc
    });
    let kernel_dot_median = median_secs(&kd_samples);
    let ks_samples = time_samples(k_reps, || ogram.matvec(&ox, &mut oyg));
    let kernel_spmv_median = median_secs(&ks_samples);
    // Forced-scalar reference arm of both workloads.
    let prev_mode = match tier {
        Tier::Scalar => KernelMode::Scalar,
        Tier::Avx2 => KernelMode::Avx2,
        Tier::Neon => KernelMode::Neon,
    };
    lsspca::kernels::force(KernelMode::Scalar)?;
    let kd_scalar = median_secs(&time_samples(k_reps, || {
        let mut acc = 0.0;
        dot_workload(&mut acc);
        acc
    }));
    let ks_scalar = median_secs(&time_samples(k_reps, || ogram.matvec(&ox, &mut oyg)));
    lsspca::kernels::force(prev_mode)?;
    let dot_speedup = kd_scalar / kernel_dot_median.max(1e-12);
    let spmv_speedup = ks_scalar / kernel_spmv_median.max(1e-12);
    metric("kernels.dot_median_secs", format!("{kernel_dot_median:.6}"));
    metric("kernels.dot_scalar_median_secs", format!("{kd_scalar:.6}"));
    metric("kernels.dot_speedup_vs_scalar", format!("{dot_speedup:.2}"));
    metric("kernels.spmv_median_secs", format!("{kernel_spmv_median:.6}"));
    metric("kernels.spmv_scalar_median_secs", format!("{ks_scalar:.6}"));
    metric("kernels.spmv_speedup_vs_scalar", format!("{spmv_speedup:.2}"));
    metric("gate.kernel_dot_median_secs", format!("{kernel_dot_median:.6}"));
    metric("gate.kernel_spmv_median_secs", format!("{kernel_spmv_median:.6}"));
    let kj = format!(
        "{{\n  \"dispatch_tier\": \"{}\",\n  \"dot\": {{\"n\": {kn}, \
         \"calls_per_sample\": 256, \"median_secs\": {kernel_dot_median:.6}, \
         \"scalar_median_secs\": {kd_scalar:.6}, \"speedup\": {dot_speedup:.3}}},\n  \
         \"spmv\": {{\"nhat\": {onhat}, \"docs\": {odocs}, \
         \"median_secs\": {kernel_spmv_median:.6}, \
         \"scalar_median_secs\": {ks_scalar:.6}, \"speedup\": {spmv_speedup:.3}}}\n}}\n",
        tier.name()
    );
    let kernels_out = PathBuf::from(args.str("kernels-out"));
    std::fs::write(&kernels_out, &kj)
        .map_err(|e| LsspcaError::io_at(&kernels_out, format!("writing bench json: {e}")))?;
    println!("wrote {}", kernels_out.display());

    // --- serve_throughput: event-loop HTTP latency → BENCH_serve.json -----
    // A live server on an ephemeral port, hammered by keep-alive clients
    // POSTing /v1 score requests; the p99 request latency is the gate
    // metric CI tracks (the serving analogue of the batch docs/s number).
    section("serve_throughput — keep-alive /v1 scoring latency (event loop)");
    let serve_model = Model {
        corpus_name: "bench-serve".into(),
        num_docs: 100,
        n_features: 32,
        vocab_hash: 0,
        seed: 1,
        elim_lambda: 0.5,
        kept: vec![3, 8, 15],
        kept_words: vec!["alpha".into(), "beta".into(), "gamma".into()],
        kept_means: vec![0.0; 3],
        kept_stds: vec![1.0; 3],
        pcs: vec![
            ModelPc {
                lambda: 0.5,
                phi: 1.0,
                explained_variance: 1.0,
                loadings: vec![(3, 0.6), (8, 0.8)],
            },
            ModelPc {
                lambda: 0.5,
                phi: 0.5,
                explained_variance: 0.5,
                loadings: vec![(15, 1.0)],
            },
        ],
    };
    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(2)
        .reload_poll_ms(0)
        .model(serve_model)
        .build()?;
    let serve_addr = server.local_addr();
    let serve_handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());
    let serve_clients = 2usize;
    let per_client: usize = if quick { 100 } else { 1000 };
    let serve_t = lsspca::util::Timer::start();
    let client_threads: Vec<_> = (0..serve_clients)
        .map(|_| {
            std::thread::spawn(move || -> Result<Vec<f64>, String> {
                use std::io::Write;
                let mut stream = std::net::TcpStream::connect(serve_addr)
                    .map_err(|e| format!("connect: {e}"))?;
                stream.set_nodelay(true).ok();
                let body = r#"{"words": [[3, 2], [8, 1], [15, 1]], "top": 2}"#;
                let req = format!(
                    "POST /v1/models/default/score HTTP/1.1\r\nHost: bench\r\n\
                     Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                    body.len()
                );
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let t = lsspca::util::Timer::start();
                    stream.write_all(req.as_bytes()).map_err(|e| format!("write: {e}"))?;
                    let status = read_bench_response(&mut stream)?;
                    if !status.starts_with("HTTP/1.1 200") {
                        return Err(format!("unexpected status: {status}"));
                    }
                    lat.push(t.secs());
                }
                Ok(lat)
            })
        })
        .collect();
    let mut serve_lat: Vec<f64> = Vec::with_capacity(serve_clients * per_client);
    for h in client_threads {
        let lat = h
            .join()
            .map_err(|_| LsspcaError::serve("bench client thread panicked"))?
            .map_err(LsspcaError::serve)?;
        serve_lat.extend(lat);
    }
    let serve_total = serve_t.secs();
    serve_handle.shutdown();
    server_thread.join().map_err(|_| LsspcaError::serve("server thread panicked"))??;
    serve_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let serve_p50 = lsspca::util::stats::percentile_sorted(&serve_lat, 0.50);
    let serve_p99 = lsspca::util::stats::percentile_sorted(&serve_lat, 0.99);
    let serve_reqs = serve_lat.len();
    let serve_rps = serve_reqs as f64 / serve_total.max(1e-12);
    metric("serve.requests", format!("{serve_reqs}"));
    metric("serve.requests_per_sec", format!("{serve_rps:.0}"));
    metric("serve.p50_secs", format!("{serve_p50:.6}"));
    metric("gate.serve_throughput_p99_secs", format!("{serve_p99:.6}"));
    let svj = format!(
        "{{\n  \"serve_throughput\": {{\"clients\": {serve_clients}, \
         \"requests\": {serve_reqs}, \"keep_alive\": true, \
         \"total_secs\": {serve_total:.6}, \"requests_per_sec\": {serve_rps:.1}, \
         \"p50_secs\": {serve_p50:.6}, \"p99_secs\": {serve_p99:.6}}}\n}}\n"
    );
    let serve_out = PathBuf::from(args.str("serve-out"));
    std::fs::write(&serve_out, &svj)
        .map_err(|e| LsspcaError::io_at(&serve_out, format!("writing bench json: {e}")))?;
    println!("wrote {}", serve_out.display());

    json.push_str(&format!(
        "  \"gate\": {{\"quick\": {quick}, \"n\": {n}, \
         \"qp_micro_median_secs\": {qp_gate_median:.6}, \
         \"fig1_speed_median_secs\": {fig1_gate_median:.6}, \
         \"oocore_disk_matvec_median_secs\": {oocore_gate_median:.6}, \
         \"session_refit_median_secs\": {session_refit_median:.6}, \
         \"session_append_median_secs\": {session_append_median:.6}, \
         \"kernel_dot_median_secs\": {kernel_dot_median:.6}, \
         \"kernel_spmv_median_secs\": {kernel_spmv_median:.6}, \
         \"serve_throughput_p99_secs\": {serve_p99:.6}}},\n"
    ));

    // --- λ-search thread scaling ------------------------------------------
    section(&format!("lambda_search — serial vs {threads} threads (same probe schedule)"));
    let ln = if quick { 96 } else { 256.min(n) };
    let lsigma = lsspca::corpus::spiked_covariance(ln, 2 * ln, (ln / 10).max(4), 3.0, &mut rng);
    let mk_opts = |t: usize| LambdaSearchOptions {
        target_card: (ln / 12).max(5),
        slack: 1,
        max_evals: 8,
        probes_per_round: 4,
        threads: t,
        bca: BcaOptions { max_sweeps: sweeps, track_history: false, ..Default::default() },
        ..Default::default()
    };
    let serial_secs = time_min(reps, || search(&lsigma, &mk_opts(1)).lambda);
    let par_secs = time_min(reps, || search(&lsigma, &mk_opts(threads)).lambda);
    let serial_res = search(&lsigma, &mk_opts(1));
    let par_res = search(&lsigma, &mk_opts(threads));
    let identical = serial_res.lambda == par_res.lambda
        && serial_res.solution.phi == par_res.solution.phi;
    let ls_speedup = serial_secs / par_secs.max(1e-12);
    metric("lambda_search.serial_secs", format!("{serial_secs:.4}"));
    metric("lambda_search.parallel_secs", format!("{par_secs:.4}"));
    metric("lambda_search.speedup", format!("{ls_speedup:.2}"));
    metric("lambda_search.identical_result", format!("{identical}"));
    json.push_str(&format!(
        "  \"lambda_search\": {{\"n\": {ln}, \"threads\": {threads}, \
         \"serial_secs\": {serial_secs:.6}, \"parallel_secs\": {par_secs:.6}, \
         \"speedup\": {ls_speedup:.3}, \"identical_result\": {identical}}}\n"
    ));
    json.push_str("}\n");

    let out = PathBuf::from(args.str("out"));
    std::fs::write(&out, &json)
        .map_err(|e| LsspcaError::io_at(&out, format!("writing bench json: {e}")))?;
    println!("\nwrote {}", out.display());

    // --- covariance-operator races → BENCH_covop.json ---------------------
    use lsspca::covop::{CovOp, DenseCov, GramCov};

    let mut cj = String::from("{\n  \"matvec_row_gather\": [\n");
    let covop_sizes: &[usize] = if quick { &[256, 1024] } else { &[512, 4096] };
    section("covop — dense vs implicit-Gram covariance operator");
    for (idx, &nhat) in covop_sizes.iter().enumerate() {
        let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(4 * nhat, nhat);
        let corpus = lsspca::corpus::SynthCorpus::new(spec, 20111212);
        let csr = corpus.to_csr();
        let t = lsspca::util::Timer::start();
        let gram = GramCov::new(csr, (4 * nhat) as u64, 64);
        let gram_build = t.secs();
        let x: Vec<f64> = (0..nhat).map(|_| rng.gauss()).collect();
        let mut y = vec![0.0; nhat];
        let mv_gram = time_min(reps + 1, || gram.matvec(&x, &mut y));
        // Row gathers over a spread sample: first touch (sparse merge)
        // vs repeat (cache hit) — measured before anything else warms
        // the cache.
        let sample: Vec<usize> = (0..32).map(|k| (k * nhat / 32) % nhat).collect();
        let mut buf = vec![0.0; nhat];
        let t = lsspca::util::Timer::start();
        for &j in &sample {
            gram.row_into(j, &mut buf);
        }
        let rg_gram_cold = t.secs();
        let rg_gram_warm = time_min(reps + 1, || {
            for &j in &sample {
                gram.row_into(j, &mut buf);
            }
        });
        // Dense operator assembled through the operator interface: one
        // n̂ × n̂ buffer (the streaming CovAccum path holds a wave of
        // partial accumulators, which at n̂ = 4096 would be GBs).
        let t = lsspca::util::Timer::start();
        let dense = DenseCov::new(gram.materialize_full());
        let dense_build = t.secs();
        let mv_dense = time_min(reps + 1, || dense.matvec(&x, &mut y));
        let rg_dense = time_min(reps + 1, || {
            for &j in &sample {
                dense.row_into(j, &mut buf);
            }
        });
        metric(&format!("covop.n{nhat}.dense_build_secs"), format!("{dense_build:.4}"));
        metric(&format!("covop.n{nhat}.gram_build_secs"), format!("{gram_build:.4}"));
        metric(&format!("covop.n{nhat}.matvec_dense_secs"), format!("{mv_dense:.6}"));
        metric(&format!("covop.n{nhat}.matvec_gram_secs"), format!("{mv_gram:.6}"));
        metric(&format!("covop.n{nhat}.rowgather32_dense_secs"), format!("{rg_dense:.6}"));
        metric(&format!("covop.n{nhat}.rowgather32_gram_cold_secs"), format!("{rg_gram_cold:.6}"));
        metric(&format!("covop.n{nhat}.rowgather32_gram_warm_secs"), format!("{rg_gram_warm:.6}"));
        cj.push_str(&format!(
            "    {{\"nhat\": {nhat}, \"dense_build_secs\": {dense_build:.6}, \
             \"gram_build_secs\": {gram_build:.6}, \"matvec_dense_secs\": {mv_dense:.6}, \
             \"matvec_gram_secs\": {mv_gram:.6}, \"rowgather32_dense_secs\": {rg_dense:.6}, \
             \"rowgather32_gram_cold_secs\": {rg_gram_cold:.6}, \
             \"rowgather32_gram_warm_secs\": {rg_gram_warm:.6}}}{}\n",
            if idx + 1 == covop_sizes.len() { "" } else { "," }
        ));
    }
    cj.push_str("  ],\n");

    // λ-search with and without per-λ nested-elimination masks.
    section("covop — λ-search with vs without per-λ elimination masks");
    let mn = if quick { 128 } else { 256 };
    let msigma = lsspca::corpus::spiked_covariance(mn, 2 * mn, 5, 6.0, &mut rng);
    let mk_mask_opts = |masks: bool| LambdaSearchOptions {
        target_card: 5,
        slack: 1,
        max_evals: 8,
        per_lambda_elim: masks,
        bca: BcaOptions { max_sweeps: sweeps, track_history: false, ..Default::default() },
        ..Default::default()
    };
    let masked_secs = time_min(reps, || search(&msigma, &mk_mask_opts(true)).lambda);
    let unmasked_secs = time_min(reps, || search(&msigma, &mk_mask_opts(false)).lambda);
    let mask_speedup = unmasked_secs / masked_secs.max(1e-12);
    metric("covop.lambda_search.masked_secs", format!("{masked_secs:.4}"));
    metric("covop.lambda_search.unmasked_secs", format!("{unmasked_secs:.4}"));
    metric("covop.lambda_search.mask_speedup", format!("{mask_speedup:.2}"));
    cj.push_str(&format!(
        "  \"lambda_search_masks\": {{\"n\": {mn}, \"masked_secs\": {masked_secs:.6}, \
         \"unmasked_secs\": {unmasked_secs:.6}, \"speedup\": {mask_speedup:.3}}}\n}}\n"
    ));

    let covop_out = PathBuf::from(args.str("covop-out"));
    std::fs::write(&covop_out, &cj)
        .map_err(|e| LsspcaError::io_at(&covop_out, format!("writing bench json: {e}")))?;
    println!("wrote {}", covop_out.display());

    // --- batch-scoring throughput → BENCH_score.json ----------------------
    // The serving-side number EXPERIMENTS.md §Serving quotes: documents
    // projected per second onto K = 5 sparse PCs through the streaming
    // batch scorer (CSV rendering included — this is the `lsspca score`
    // hot path, not a stripped-down kernel).
    use lsspca::score::score_stream;
    use lsspca::stream::SynthSource;

    section("scoring — batch projection throughput (docs/s onto 5 sparse PCs)");
    let sdocs = if quick { 2_000 } else { 20_000 };
    let scorpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(sdocs, 2000), 20111213);
    let planted = scorpus.planted_ids();
    let smodel = Model {
        corpus_name: "bench-scoring".into(),
        num_docs: sdocs as u64,
        n_features: scorpus.spec.vocab_size,
        vocab_hash: 0,
        seed: scorpus.seed,
        elim_lambda: 0.5,
        kept_means: vec![0.1; planted.len()],
        kept_stds: vec![1.0; planted.len()],
        kept_words: planted.iter().map(|&i| scorpus.vocab.word(i)).collect(),
        kept: planted,
        pcs: scorpus
            .topic_word_ids
            .iter()
            .map(|ids| ModelPc {
                lambda: 0.5,
                phi: 1.0,
                explained_variance: 1.0,
                loadings: ids.iter().map(|&i| (i, 1.0 / (ids.len() as f64).sqrt())).collect(),
            })
            .collect(),
    };
    let scorer = Scorer::new(&smodel, ScoreOptions::default())?;
    let mut sj = String::from("{\n  \"batch_scoring\": [\n");
    let thread_arms: Vec<usize> = if threads > 1 { vec![1, threads] } else { vec![1] };
    for (idx, &t) in thread_arms.iter().enumerate() {
        let opts = BatchOptions { threads: t, chunk_docs: 1024, top: 1 };
        let mut sink = std::io::sink();
        let stats = score_stream(&mut SynthSource::new(&scorpus), &scorer, opts, &mut sink)?;
        let rate = stats.docs_per_sec();
        metric(&format!("scoring.t{t}.docs_per_sec"), format!("{rate:.0}"));
        sj.push_str(&format!(
            "    {{\"threads\": {t}, \"docs\": {sdocs}, \"k\": {}, \"secs\": {:.6}, \
             \"docs_per_sec\": {rate:.1}}}{}\n",
            scorer.num_pcs(),
            stats.seconds,
            if idx + 1 == thread_arms.len() { "" } else { "," }
        ));
    }
    sj.push_str("  ]\n}\n");
    let score_out = PathBuf::from(args.str("score-out"));
    std::fs::write(&score_out, &sj)
        .map_err(|e| LsspcaError::io_at(&score_out, format!("writing bench json: {e}")))?;
    println!("wrote {}", score_out.display());

    let oocore_out = PathBuf::from(args.str("oocore-out"));
    std::fs::write(&oocore_out, &oj)
        .map_err(|e| LsspcaError::io_at(&oocore_out, format!("writing bench json: {e}")))?;
    println!("wrote {}", oocore_out.display());

    // --- regression gate vs a committed baseline --------------------------
    let baseline = args.str("compare");
    if !baseline.is_empty() {
        bench_compare_gate(
            Path::new(&baseline),
            &[
                ("qp_micro_median_secs", qp_gate_median),
                ("fig1_speed_median_secs", fig1_gate_median),
                ("oocore_disk_matvec_median_secs", oocore_gate_median),
                ("session_refit_median_secs", session_refit_median),
                ("session_append_median_secs", session_append_median),
                ("kernel_dot_median_secs", kernel_dot_median),
                ("kernel_spmv_median_secs", kernel_spmv_median),
                ("serve_throughput_p99_secs", serve_p99),
            ],
            quick,
            n,
            args.f64("max-regress")?,
        )?;
    }
    Ok(())
}

/// Hidden worker entrypoint for the distributed corpus pass: the
/// coordinator re-execs this binary as `lsspca worker --manifest <path>
/// --shard <i>` — see [`lsspca::dist`]. Faults arrive through the
/// inherited `LSSPCA_FAULTS` environment, so kill scripts hit workers
/// without any extra plumbing.
fn cmd_worker(args: &Args) -> Result<(), LsspcaError> {
    let manifest = PathBuf::from(args.str("manifest"));
    let shard = args.usize("shard")?;
    lsspca::dist::worker::run_worker(&manifest, shard)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(e.exit_code());
        }
    };
    let result: Result<(), LsspcaError> = match parsed {
        Parsed::Help(text) => {
            println!("{text}");
            Ok(())
        }
        Parsed::Command(name, args) => match name.as_str() {
            "run" => cmd_run(&args),
            "export" => cmd_export(&args),
            "score" => cmd_score(&args),
            "serve" => cmd_serve(&args),
            "watch" => cmd_watch(&args),
            "dlq" => cmd_dlq(&args),
            "gen" => cmd_gen(&args),
            "variances" => cmd_variances(&args),
            "solve" => cmd_solve(&args),
            "artifacts" => cmd_artifacts(&args),
            "bench" => cmd_bench(&args),
            "worker" => cmd_worker(&args),
            _ => unreachable!("parser rejects unknown commands"),
        },
    };
    // Distinct exit codes per error class (config=2, io=3, cache=4,
    // numeric=5, corpus=6, serve=7) so shell callers can branch on the
    // failure kind; success stays 0.
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(e.exit_code());
    }
}
