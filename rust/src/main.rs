//! `lsspca` — command-line entrypoint for the Large-Scale Sparse PCA
//! pipeline (Zhang & El Ghaoui, NIPS 2011 reproduction).
//!
//! ```text
//! lsspca run        --preset nytimes --pcs 5 --target-card 5     # full pipeline
//! lsspca gen        --preset pubmed --docs 100000 --out corpus.txt.gz
//! lsspca variances  --input corpus.txt.gz                        # Fig 2 profile
//! lsspca solve      --n 200 --lambda 0.5 --model spiked          # solver on synthetic Σ
//! lsspca artifacts  --dir artifacts                              # inspect AOT artifacts
//! ```

use std::path::{Path, PathBuf};

use lsspca::cli::{App, Args, CommandSpec, Parsed};
use lsspca::config::PipelineConfig;
use lsspca::coordinator::Pipeline;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::data::Vocab;
use lsspca::prelude::*;
use lsspca::solver::bca;
use lsspca::stream::{variance_pass_file, StreamOptions};
use lsspca::util::plot::AsciiPlot;
use lsspca::util::rng::Rng;

fn app() -> App {
    App::new("lsspca", "large-scale sparse PCA (NIPS 2011 reproduction)")
        .command(
            CommandSpec::new("run", "full pipeline: stream → eliminate → solve → topics")
                .opt("config", "", "TOML config file (flags override)")
                .opt("input", "", "docword file (empty = synthetic preset)")
                .opt("preset", "nytimes", "synthetic preset: nytimes|pubmed")
                .opt("docs", "0", "synthetic docs (0 = preset default)")
                .opt("vocab", "0", "synthetic vocab (0 = preset default)")
                .opt("seed", "20111212", "corpus seed")
                .opt("pcs", "5", "number of sparse PCs")
                .opt("target-card", "5", "target cardinality per PC")
                .opt("max-reduced", "512", "cap on reduced problem size")
                .opt("workers", "2", "moment-pass worker threads")
                .opt("engine", "native", "solver engine: native|xla")
                .opt("artifacts", "artifacts", "artifact dir for --engine xla")
                .opt("cache-dir", "", "variance-checkpoint dir (reused across runs)")
                .switch("certify", "compute a dual optimality certificate per PC")
                .switch("profile", "print the timing profile"),
        )
        .command(
            CommandSpec::new("gen", "generate a synthetic corpus to disk (UCI docword format)")
                .req("out", "output path (.gz for gzip)")
                .opt("preset", "nytimes", "nytimes|pubmed")
                .opt("docs", "0", "documents (0 = preset default)")
                .opt("vocab", "0", "vocabulary (0 = preset default)")
                .opt("seed", "20111212", "seed"),
        )
        .command(
            CommandSpec::new("variances", "streamed variance profile of a docword file (Fig 2)")
                .req("input", "docword file")
                .opt("workers", "2", "worker threads")
                .opt("top", "20", "print the top-k features"),
        )
        .command(
            CommandSpec::new("solve", "run BCA on a synthetic covariance model")
                .opt("n", "100", "problem size")
                .opt("m", "300", "samples for the covariance model")
                .opt("model", "spiked", "spiked|gaussian")
                .opt("card", "10", "spike cardinality (spiked model)")
                .opt("lambda", "-1", "penalty λ (-1 = auto from variances)")
                .opt("sweeps", "20", "max BCA sweeps")
                .opt("seed", "7", "model seed"),
        )
        .command(
            CommandSpec::new("artifacts", "load and list AOT artifacts through PJRT")
                .opt("dir", "artifacts", "artifact directory"),
        )
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let mut cfg = if args.str("config").is_empty() {
        PipelineConfig::default()
    } else {
        PipelineConfig::load(Path::new(&args.str("config")))?
    };
    // flags override config-file values
    if !args.str("input").is_empty() {
        cfg.input = args.str("input");
    }
    cfg.synth_preset = args.str("preset");
    if args.usize("docs")? > 0 {
        cfg.synth_docs = args.usize("docs")?;
    }
    if args.usize("vocab")? > 0 {
        cfg.synth_vocab = args.usize("vocab")?;
    }
    cfg.seed = args.u64("seed")?;
    cfg.num_pcs = args.usize("pcs")?;
    cfg.target_card = args.usize("target-card")?;
    cfg.max_reduced = args.usize("max-reduced")?;
    cfg.workers = args.usize("workers")?;
    cfg.engine = args.str("engine");
    cfg.artifacts_dir = args.str("artifacts");
    if !args.str("cache-dir").is_empty() {
        cfg.cache_dir = args.str("cache-dir");
    }
    cfg.certify = cfg.certify || args.switch("certify");
    cfg.validate()?;

    let report = Pipeline::new(cfg).run()?;
    println!("\n# {} — sparse PCA report", report.corpus_name);
    println!(
        "docs={} vocab={} nnz={} | reduced n̂={} ({}x reduction, λ̂={:.4e}{})",
        report.num_docs,
        report.vocab_size,
        report.nnz,
        report.reduced_size,
        report.reduction_factor as u64,
        report.elim_lambda,
        if report.elim_capped { ", capped" } else { "" }
    );
    println!("\n{}", report.topic_table);
    for (k, c) in report.components.iter().enumerate() {
        let cert = c
            .certificate_gap
            .map(|g| format!(" gap≤{g:.2e}"))
            .unwrap_or_default();
        println!(
            "PC{}: card={} λ={:.4} φ={:.4} explained={:.4} ({:.2}s){cert}",
            k + 1,
            c.pc.cardinality(),
            c.lambda,
            c.phi,
            c.explained_variance,
            c.seconds
        );
    }
    println!("\ntotal: {:.2}s", report.total_seconds);
    if args.switch("profile") {
        println!("\n{}", report.profile);
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<(), String> {
    let spec = CorpusSpec::preset(&args.str("preset"))
        .ok_or("unknown preset")?
        .scaled(args.usize("docs")?, args.usize("vocab")?);
    let corpus = SynthCorpus::new(spec, args.u64("seed")?);
    let out = PathBuf::from(args.str("out"));
    let t = lsspca::util::Timer::start();
    let hdr = corpus.write_docword(&out)?;
    println!(
        "wrote {}: D={} W={} NNZ={} in {:.1}s (+ vocab at {})",
        out.display(),
        hdr.num_docs,
        hdr.vocab_size,
        hdr.nnz,
        t.secs(),
        out.with_extension("vocab").display()
    );
    Ok(())
}

fn cmd_variances(args: &Args) -> Result<(), String> {
    let input = PathBuf::from(args.str("input"));
    let opts = StreamOptions { workers: args.usize("workers")?, ..Default::default() };
    let (hdr, fv, stats) = variance_pass_file(&input, opts)?;
    let sorted = fv.sorted_variances();
    println!(
        "D={} W={} NNZ={} | pass took {:.2}s with {} workers",
        hdr.num_docs, hdr.vocab_size, hdr.nnz, stats.seconds, opts.workers
    );
    let pts: Vec<(f64, f64)> = sorted
        .iter()
        .enumerate()
        .filter(|&(_, &v)| v > 0.0)
        .map(|(i, &v)| ((i + 1) as f64, v))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("sorted word variances (cf. paper Fig 2)")
            .logx()
            .logy()
            .series("variance", '*', &pts)
            .render()
    );
    let vocab_path = input.with_extension("vocab");
    let vocab = if vocab_path.exists() { Vocab::load(&vocab_path)? } else { Vocab::default() };
    println!("top features by variance:");
    for (rank, (idx, var)) in fv.ranked().into_iter().take(args.usize("top")?).enumerate() {
        println!("  {:>3}. {:<20} {var:.4}", rank + 1, vocab.word(idx));
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<(), String> {
    let n = args.usize("n")?;
    let m = args.usize("m")?;
    let mut rng = Rng::seed_from(args.u64("seed")?);
    let sigma = match args.str("model").as_str() {
        "spiked" => {
            lsspca::corpus::spiked_covariance(n, m, args.usize("card")?.min(n), 2.0, &mut rng)
        }
        "gaussian" => lsspca::corpus::gaussian_factor_cov(n, m, &mut rng),
        other => return Err(format!("unknown model '{other}'")),
    };
    let mut lambda = args.f64("lambda")?;
    if lambda < 0.0 {
        let diags: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        lambda = lsspca::elim::lambda_for_survivors(&diags, (2 * args.usize("card")?).max(10));
        println!("auto λ = {lambda:.4}");
    }
    let opts = BcaOptions { max_sweeps: args.usize("sweeps")?, ..Default::default() };
    let sol = bca::solve(&sigma, lambda, &opts);
    let pc = lsspca::solver::extract::leading_sparse_pc(&sol.z, 1e-4);
    println!(
        "φ={:.6} sweeps={} final_delta={:.2e} time={:.2}s",
        sol.phi, sol.sweeps, sol.final_delta, sol.seconds
    );
    println!("support ({}): {:?}", pc.cardinality(), pc.support);
    let series: Vec<(f64, f64)> = sol
        .history
        .iter()
        .map(|h| (h.seconds.max(1e-6), h.objective))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("objective vs time")
            .series("BCA", 'o', &series)
            .render()
    );
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = PathBuf::from(args.str("dir"));
    let mut rt = lsspca::runtime::Runtime::new().map_err(|e| format!("{e:#}"))?;
    let names = rt.load_dir(&dir).map_err(|e| format!("{e:#}"))?;
    println!("loaded {} artifacts from {}:", names.len(), dir.display());
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match app().parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match parsed {
        Parsed::Help(text) => {
            println!("{text}");
            Ok(())
        }
        Parsed::Command(name, args) => match name.as_str() {
            "run" => cmd_run(&args),
            "gen" => cmd_gen(&args),
            "variances" => cmd_variances(&args),
            "solve" => cmd_solve(&args),
            "artifacts" => cmd_artifacts(&args),
            _ => unreachable!("parser rejects unknown commands"),
        },
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
