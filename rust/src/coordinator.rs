//! The end-to-end pipeline — the paper's §4 workflow as one coordinator:
//!
//! ```text
//! corpus (file or synthetic)
//!   → streamed variance pass (sharded workers, backpressure)      stream/moments
//!   → safe feature elimination at λ̂ for the target cardinality    elim
//!   → streamed reduced-covariance pass                            cov
//!   → λ-search + BCA solve (native or XLA engine)                 solver/engine
//!   → deflate, repeat for num_pcs components                      solver::deflate
//!   → topic table + metrics                                       report
//!   → model artifact (original-space PCs + norm stats)            model
//! ```
//!
//! **Migration note:** [`Pipeline::run`] is now a thin compatibility
//! wrapper over the staged [`crate::session::Session`] API — the stages
//! above are `stream() → eliminate(k) → reduce() → fit(λ, K)`, each
//! individually callable and cached. Code that only needs the one-shot
//! report keeps working unchanged (results are bitwise-identical);
//! code that re-solves at several `(λ, K)` should hold a `Session` and
//! call `fit` repeatedly instead of re-running the pipeline. Errors are
//! now the structured [`LsspcaError`] instead of `String`.
//!
//! Deflation note: components after the first are extracted from the same
//! reduced covariance operator, re-solving after stacking earlier PCs as
//! rank-K corrections ([`DeflatedCov`](crate::solver::deflate::DeflatedCov))
//! — the paper's "top 5 sparse principal components" workflow, without
//! destructive dense edits. The initial λ̂ for *elimination* is chosen from
//! the variance profile so the reduced problem comfortably contains a
//! cardinality-`target` solution (`max_reduced` caps it; the cap is
//! reported when it binds).
//!
//! Covariance backend (`cov.backend`): `"dense"` streams the reduced
//! n̂ × n̂ matrix exactly as before (every solve bitwise the historical
//! pipeline; components after the first agree to ~1e-9 because deflation
//! reassociates the destructive updates' arithmetic);
//! `"gram"` streams the reduced sparse term matrix instead and serves Σ
//! implicitly through [`crate::covop::GramCov`] — O(nnz) memory plus a
//! bounded row cache, so n̂ can reach tens of thousands; `"disk"`
//! persists that matrix to the shard cache once and streams it through
//! [`crate::cov_disk::DiskGramCov`] under `[memory] budget_mb` (solves
//! bitwise-identical to `"gram"`); `"auto"` lets [`plan_backend`] — the
//! memory-budget planner — pick from variance-pass footprint estimates,
//! logging the numbers behind the decision.
//!
//! Distributed note: with `[dist] workers > 0` the two corpus passes run
//! as coordinator + worker *processes* ([`crate::dist`]) instead of
//! in-process thread pools; results stay bitwise identical and the
//! stages, caching, and λ-search above are unchanged.

use std::path::Path;
use std::sync::Arc;

use crate::config::PipelineConfig;
use crate::covop::{CovOp, MaskedCov};
use crate::elim::{lambda_for_survivors, SafeElimination};
use crate::engine::Engine;
use crate::error::LsspcaError;
use crate::moments::FeatureVariances;
use crate::session::{LambdaSpec, Progress, Session};
use crate::solver::extract::SparsePc;
use crate::solver::lambda::{LambdaEval, LambdaSearchOptions};
use crate::util::timer::Timer;

/// One extracted component with its reporting metadata.
#[derive(Clone, Debug)]
pub struct ComponentReport {
    /// The sparse PC in *reduced* coordinates.
    pub pc: SparsePc,
    /// λ chosen by the cardinality search.
    pub lambda: f64,
    /// Problem-(1) objective.
    pub phi: f64,
    /// Explained variance on the (deflated) reduced covariance.
    pub explained_variance: f64,
    /// Words (or `wNNNNN` labels) of the support, by decreasing |loading|.
    pub words: Vec<String>,
    /// Wall seconds to find this PC (λ-search + solves).
    pub seconds: f64,
    /// Dual optimality gap (upper bound − φ), when `solver.certify` is on.
    pub certificate_gap: Option<f64>,
}

/// Full pipeline output.
#[derive(Debug)]
pub struct PipelineReport {
    /// Corpus name (preset) or input path.
    pub corpus_name: String,
    /// Documents streamed.
    pub num_docs: usize,
    /// Original vocabulary size n.
    pub vocab_size: usize,
    /// Corpus nonzeros streamed in pass 1.
    pub nnz: u64,
    /// Sorted variance profile (Fig 2 series).
    pub sorted_variances: Vec<f64>,
    /// Reduced problem size n̂ after elimination (E5 headline).
    pub reduced_size: usize,
    /// `n / n̂`.
    pub reduction_factor: f64,
    /// λ̂ the elimination ran at.
    pub elim_lambda: f64,
    /// Whether `max_reduced` bound the reduction.
    pub elim_capped: bool,
    /// One entry per extracted sparse PC.
    pub components: Vec<ComponentReport>,
    /// Second-level timing profile.
    pub profile: String,
    /// End-to-end wall seconds.
    pub total_seconds: f64,
    /// Markdown topic table (the paper's Tables 1–2 format).
    pub topic_table: String,
    /// The serving artifact: original-space sparse PCs plus the
    /// elimination map and normalization statistics (always built — it
    /// is a few KiB; written to disk when `model.save_path` is set).
    pub model: crate::model::Model,
}

/// The one-shot pipeline object: configuration (+ optional observer).
///
/// A compatibility wrapper over [`Session`]: `run` executes
/// `stream → eliminate → reduce → fit` once and assembles the classic
/// [`PipelineReport`]. Hold a [`Session`] directly to reuse the
/// streamed corpus across many fits.
pub struct Pipeline {
    /// The full run configuration.
    pub config: PipelineConfig,
    observer: Option<Arc<dyn Progress>>,
}

impl Pipeline {
    /// Wrap a validated configuration.
    pub fn new(config: PipelineConfig) -> Pipeline {
        Pipeline { config, observer: None }
    }

    /// Attach a [`Progress`] observer to the run.
    pub fn with_observer(mut self, observer: Arc<dyn Progress>) -> Pipeline {
        self.observer = Some(observer);
        self
    }

    /// Run end-to-end. `input` resolution: configured file path, else a
    /// synthetic corpus streamed straight from the generator.
    ///
    /// Equivalent to a fresh [`Session`] running every stage once with
    /// this configuration — bitwise-identical components, same logs,
    /// same profile sections.
    pub fn run(&self) -> Result<PipelineReport, LsspcaError> {
        let total = Timer::start();
        let mut session = Session::from_config(self.config.clone())?;
        if let Some(obs) = &self.observer {
            session.set_observer(Arc::clone(obs));
        }
        let fit = session.fit(LambdaSpec::from_config(&self.config), self.config.num_pcs)?;
        let (corpus_name, num_docs, vocab_size, nnz, sorted_variances) = {
            let stats = session.stream()?;
            (
                stats.corpus_name.clone(),
                stats.docs as usize,
                stats.vocab_size(),
                stats.nnz,
                stats.variances.sorted_variances(),
            )
        };
        let (reduced_size, reduction_factor, elim_lambda, elim_capped) = {
            let plan = session.eliminate(self.config.target_card)?;
            (
                plan.elim.reduced(),
                plan.elim.reduction_factor(),
                plan.elim.lambda,
                plan.capped,
            )
        };
        if !self.config.save_model.is_empty() {
            fit.model.save(Path::new(&self.config.save_model))?;
            crate::info!("model artifact written to {}", self.config.save_model);
        }
        Ok(PipelineReport {
            corpus_name,
            num_docs,
            vocab_size,
            nnz,
            sorted_variances,
            reduced_size,
            reduction_factor,
            elim_lambda,
            elim_capped,
            components: fit.components,
            profile: session.profile(),
            total_seconds: total.secs(),
            topic_table: fit.topic_table,
            model: fit.model,
        })
    }
}

/// Choose the elimination λ̂ for a target PC cardinality: keep a working
/// set comfortably larger than the target (the λ-search then operates
/// inside it), capped at `max_reduced`. Returns the elimination and
/// whether the cap bound.
pub fn choose_elimination(
    fv: &FeatureVariances,
    target_card: usize,
    max_reduced: usize,
) -> (SafeElimination, bool) {
    // Working set ~ 40× the target cardinality mirrors the paper's
    // observation (target 5 → n̂ ≤ ~500 on NYTimes within a ~100k vocab).
    let want = (target_card * 40).min(max_reduced).max(target_card);
    let lam = lambda_for_survivors(&fv.variance, want);
    let elim = SafeElimination::from_variances(fv, lam, Some(max_reduced));
    let capped = elim.capped(&fv.variance);
    (elim, capped)
}

/// Outcome of the memory-budget planner: the chosen backend and the
/// footprint estimates (in bytes) the decision was based on.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    /// Reduced problem size n̂ the estimates assume.
    pub nhat: usize,
    /// Estimated peak resident bytes of the dense backend (streaming
    /// assembly holds one n̂ × n̂ partial per worker, plus Σ itself and
    /// the solver iterate).
    pub dense_bytes: u64,
    /// Estimated resident bytes of the in-memory gram backend (CSR +
    /// CSC of the reduced matrix, bounded above via the variance-pass
    /// per-feature counts, plus the row cache).
    pub gram_bytes: u64,
    /// Resident floor of the disk backend (one streaming wave of shards;
    /// the row cache then takes whatever budget remains).
    pub disk_bytes: u64,
    /// The configured budget in bytes (0 = unlimited).
    pub budget_bytes: u64,
    /// The backend the planner picked: "dense", "gram" or "disk".
    pub backend: String,
    /// One-line human reason for the choice.
    pub reason: String,
}

impl MemoryPlan {
    /// Render the full decision — estimates and reason — for the log.
    pub fn describe(&self) -> String {
        let mb = |b: u64| (b as f64 / (1024.0 * 1024.0)).ceil() as u64;
        format!(
            "n̂={} budget={} dense≈{} MiB gram≈{} MiB disk≥{} MiB → backend={} ({})",
            self.nhat,
            if self.budget_bytes == 0 {
                "unlimited".to_string()
            } else {
                format!("{} MiB", mb(self.budget_bytes))
            },
            mb(self.dense_bytes),
            mb(self.gram_bytes),
            mb(self.disk_bytes),
            self.backend,
            self.reason
        )
    }
}

/// The memory-budget planner behind `[cov] backend = "auto"`: estimate
/// the dense / gram / disk covariance footprints from the variance-pass
/// statistics and pick the cheapest-to-serve backend that fits
/// `[memory] budget_mb`.
///
/// Estimates (all deliberately upper bounds — the planner must never
/// pick a backend that then blows the budget):
///
/// - **dense**: `(workers + 2) · 8n̂²` — the streaming assembly holds one
///   n̂ × n̂ partial accumulator per worker, then Σ plus the solver
///   iterate X stay resident.
/// - **gram**: `24 · nnẑ + row_cache` where `nnẑ = Σ_{j kept}
///   min(m, m·μ_j)` bounds the reduced matrix's nonzeros via the
///   variance-pass per-feature means (counts ≥ 1 ⇒ doc-frequency ≤
///   total count), and 24 bytes/nnz covers the CSR + CSC pair.
/// - **disk**: `(threads + 1) · max(shard_mb, largest column) +
///   8·rows` — one decode wave of shards plus the dense `A·x` scratch
///   every matvec/quadratic form holds (one f64 per reduced row,
///   bounded above by `min(m, nnẑ)` since each reduced row has ≥ 1
///   nonzero). A column whose payload alone exceeds `shard_mb` becomes
///   one oversized shard (`plan_shards` never splits a column), so the
///   wave term uses the larger of the configured shard size and the
///   biggest kept column's estimated bytes. The Σ-row cache is then
///   *sized from* the remaining budget rather than estimated (see
///   [`disk_row_cache_mb`]).
///
/// With no budget configured (`budget_mb = 0`) the planner keeps the
/// historical default, dense; under the XLA engine it pins dense
/// outright (the artifacts need an explicit matrix).
pub fn plan_backend(
    fv: &FeatureVariances,
    elim: &SafeElimination,
    cfg: &PipelineConfig,
) -> MemoryPlan {
    const MIB: u64 = 1024 * 1024;
    let nhat = elim.reduced() as u64;
    let m = fv.docs;
    let dense_bytes = (cfg.workers as u64 + 2) * 8 * nhat * nhat;
    let col_nnz_est = |j: usize| (fv.mean[j] * m as f64).min(m as f64).max(0.0);
    let nnz_est: f64 = elim.kept.iter().map(|&j| col_nnz_est(j)).sum();
    let gram_bytes = (24.0 * nnz_est) as u64 + cfg.row_cache_mb as u64 * MIB;
    let wave = crate::util::parallel::resolve_threads(cfg.threads) as u64 + 1;
    // A single column larger than shard_mb becomes one oversized shard,
    // so the wave term must use the larger of the two.
    let max_col_bytes = elim
        .kept
        .iter()
        .map(|&j| (12.0 * col_nnz_est(j)) as u64)
        .max()
        .unwrap_or(0);
    // Every matvec/quad form also holds one dense A·x scratch of one
    // f64 per reduced row (rows ≤ min(m, nnẑ): each row has ≥ 1 nnz).
    let ax_bytes = 8 * (m.min(nnz_est as u64));
    let disk_bytes = wave * (cfg.shard_mb as u64 * MIB).max(max_col_bytes) + ax_bytes;
    let budget_bytes = cfg.memory_budget_mb as u64 * MIB;
    let (backend, reason) = if cfg.engine == "xla" {
        ("dense", "xla engine needs an explicit dense Σ".to_string())
    } else if budget_bytes == 0 {
        ("dense", "no memory budget configured; keeping the default".to_string())
    } else if dense_bytes <= budget_bytes {
        ("dense", "dense fits the budget".to_string())
    } else if gram_bytes <= budget_bytes {
        ("gram", "dense exceeds the budget, implicit gram fits".to_string())
    } else if disk_bytes <= budget_bytes {
        ("disk", "only the out-of-core backend fits the budget".to_string())
    } else {
        (
            "disk",
            format!(
                "nothing fits the budget (disk floor ≈ {} MiB); \
                 falling back to disk, the smallest-footprint backend",
                disk_bytes.div_ceil(MIB)
            ),
        )
    };
    MemoryPlan {
        nhat: elim.reduced(),
        dense_bytes,
        gram_bytes,
        disk_bytes,
        budget_bytes,
        backend: backend.to_string(),
        reason,
    }
}

/// Σ-row cache budget (MiB) for the disk backend: whatever remains of
/// `[memory] budget_mb` after one streaming wave of shards, or the
/// `row_cache_mb` default when no budget is configured. The wave is
/// priced at the **actual** largest shard (`max_shard_bytes`, from the
/// manifest) rather than the configured `shard_mb`, because a column
/// bigger than the configured budget becomes one oversized shard. May
/// return 0 — the cache never changes a value, only wall time.
pub fn disk_row_cache_mb(cfg: &PipelineConfig, max_shard_bytes: u64) -> usize {
    if cfg.memory_budget_mb == 0 {
        return cfg.row_cache_mb;
    }
    const MIB: u64 = 1024 * 1024;
    let wave = crate::util::parallel::resolve_threads(cfg.threads) as u64 + 1;
    let shard = (cfg.shard_mb as u64 * MIB).max(max_shard_bytes);
    let reserve_mb = (wave * shard).div_ceil(MIB) as usize;
    cfg.memory_budget_mb.saturating_sub(reserve_mb)
}

/// λ-search where the inner solves run on an [`Engine`].
pub fn search_with_engine(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    opts: &LambdaSearchOptions,
) -> Result<crate::solver::lambda::LambdaSearchResult, LsspcaError> {
    search_with_engine_observed(engine, sigma, opts, &mut |_| {})
}

/// [`search_with_engine`] with a per-evaluation callback (the λ-grid
/// progress feed — see [`crate::solver::lambda::search_observed`]). The
/// callback cannot change the search result.
pub fn search_with_engine_observed(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    opts: &LambdaSearchOptions,
    on_eval: &mut dyn FnMut(&LambdaEval),
) -> Result<crate::solver::lambda::LambdaSearchResult, LsspcaError> {
    match engine.name() {
        // The native fast path uses the allocation-free direct solver.
        "native" => Ok(crate::solver::lambda::search_observed(sigma, opts, on_eval)),
        _ => {
            // Engine-generic path: replicate the search but solve via engine.
            let mut eopts = *opts;
            eopts.bca.track_history = false;
            engine_search(engine, sigma, &eopts, on_eval)
        }
    }
}

/// One engine-path probe at a fixed λ: per-λ safe elimination
/// (Thm 2.1, mirroring [`crate::solver::lambda::evaluate`]'s native
/// logic), [`crate::engine::bca_solve`] on the masked survivor view,
/// and the lift back to the caller's coordinates. Shared by
/// [`search_with_engine_observed`]'s bracketing loop and the session's
/// fixed-λ grid path — the masked-probe logic exists exactly once per
/// solver path, so the "grid point ≡ search probe" bitwise pin cannot
/// drift between them. `diags` is Σ's full diagonal, hoisted by the
/// caller (a search evaluates many probes against the same diagonal,
/// which is O(k) per entry on a deflated operator).
pub(crate) fn engine_probe(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    diags: &[f64],
    lambda: f64,
    opts: &LambdaSearchOptions,
) -> Result<(crate::solver::bca::BcaSolution, SparsePc), LsspcaError> {
    use crate::solver::extract::leading_sparse_pc;
    let n = sigma.n();
    let elim = crate::elim::SafeElimination::apply(diags, lambda, None);
    let use_mask = opts.per_lambda_elim && elim.reduced() != n && elim.reduced() != 0;
    if !use_mask {
        let sol = crate::engine::bca_solve(engine, sigma, lambda, &opts.bca)?;
        let pc = leading_sparse_pc(&sol.z, opts.extract_tol);
        Ok((sol, pc))
    } else {
        let sub = MaskedCov::new(sigma, elim.kept.clone());
        let sol = crate::engine::bca_solve(engine, &sub, lambda, &opts.bca)?;
        let pc = leading_sparse_pc(&sol.z, opts.extract_tol).mapped(&elim.kept, n);
        Ok((sol, pc))
    }
}

fn engine_search(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    opts: &LambdaSearchOptions,
    on_eval: &mut dyn FnMut(&LambdaEval),
) -> Result<crate::solver::lambda::LambdaSearchResult, LsspcaError> {
    use crate::solver::lambda::LambdaSearchResult;
    let n = sigma.n();
    let max_diag = (0..n).map(|i| sigma.diag(i)).fold(0.0f64, f64::max);
    let (mut lo, mut hi) = (0.0f64, max_diag * 0.999);
    let mut lambda = 0.5 * hi;
    let mut trace = Vec::new();
    let mut best: Option<(f64, crate::solver::bca::BcaSolution, SparsePc)> = None;
    let mut best_key = (usize::MAX, f64::NEG_INFINITY);
    let diags: Vec<f64> = (0..n).map(|i| sigma.diag(i)).collect();
    for evals in 0..opts.max_evals {
        let (sol, pc) = engine_probe(engine, sigma, &diags, lambda, opts)?;
        let card = pc.cardinality();
        trace.push(LambdaEval { lambda, cardinality: card, phi: sol.phi });
        on_eval(trace.last().expect("just pushed"));
        let key = (card.abs_diff(opts.target_card), sol.phi);
        if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 > best_key.1) {
            best_key = key;
            best = Some((lambda, sol, pc));
        }
        let dist = card.abs_diff(opts.target_card);
        if dist == 0 || (dist <= opts.slack && evals + 1 >= opts.max_evals / 2) {
            break;
        }
        if card > opts.target_card {
            lo = lambda;
        } else {
            hi = lambda;
        }
        lambda = 0.5 * (lo + hi);
        if (hi - lo) < 1e-12 * (1.0 + max_diag) {
            break;
        }
    }
    let (lambda, solution, pc) =
        best.ok_or_else(|| LsspcaError::numeric("no evaluations"))?;
    let hit_target = pc.cardinality().abs_diff(opts.target_card) <= opts.slack;
    Ok(LambdaSearchResult { lambda, solution, pc, trace, hit_target })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusSpec;

    fn tiny_config() -> PipelineConfig {
        PipelineConfig {
            synth_preset: "nytimes".into(),
            synth_docs: 800,
            synth_vocab: 3000,
            workers: 2,
            chunk_docs: 128,
            num_pcs: 3,
            target_card: 5,
            card_slack: 2,
            max_reduced: 64,
            bca_sweeps: 6,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_tiny_nytimes() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        assert_eq!(report.num_docs, 800);
        assert!(report.reduced_size > 0 && report.reduced_size <= 64);
        assert!(report.reduction_factor > 10.0, "reduction {}", report.reduction_factor);
        assert_eq!(report.components.len(), 3);
        for c in &report.components {
            assert!(c.pc.cardinality() >= 1);
            assert!(c.pc.cardinality() <= 5 + 4, "card {}", c.pc.cardinality());
            assert!(!c.words.is_empty());
        }
        // topic table mentions at least one planted word from Table 1
        let planted = ["million", "percent", "point", "play", "official", "president", "school"];
        assert!(
            planted.iter().any(|w| report.topic_table.contains(w)),
            "topic table:\n{}",
            report.topic_table
        );
        // Fig 2 series is sorted descending
        assert!(report
            .sorted_variances
            .windows(2)
            .all(|w| w[0] >= w[1]));
    }

    #[test]
    fn first_pc_recovers_a_planted_topic() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        // The strongest PC should consist mostly of words from ONE topic.
        let spec = CorpusSpec::nytimes();
        let first = &report.components[0];
        let mut best_overlap = 0usize;
        for t in &spec.topics {
            let overlap = first
                .words
                .iter()
                .filter(|w| t.words.contains(&w.as_str()))
                .count();
            best_overlap = best_overlap.max(overlap);
        }
        assert!(
            best_overlap * 2 >= first.words.len(),
            "PC1 words {:?} do not concentrate on one topic",
            first.words
        );
    }

    #[test]
    fn report_model_is_consistent_with_components() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        let m = &report.model;
        m.validate().unwrap();
        assert_eq!(m.n_features, report.vocab_size);
        assert_eq!(m.kept.len(), report.reduced_size);
        assert_eq!(m.pcs.len(), report.components.len());
        assert_eq!(m.num_docs as usize, report.num_docs);
        for (c, pc) in report.components.iter().zip(&m.pcs) {
            assert_eq!(pc.loadings.len(), c.pc.cardinality());
            // original-space loadings are the reduced PC pushed through
            // the kept map, bit for bit, in the same support order
            for (&(orig, w), &r) in pc.loadings.iter().zip(&c.pc.support) {
                assert_eq!(orig, m.kept[r]);
                assert_eq!(w.to_bits(), c.pc.vector[r].to_bits());
            }
            assert_eq!(pc.lambda, c.lambda);
        }
        // the model's top word per PC matches the reported word list
        for (c, pc) in report.components.iter().zip(&m.pcs) {
            assert_eq!(m.word_of(pc.loadings[0].0), c.words[0]);
        }
    }

    #[test]
    fn memory_planner_picks_backend_by_budget() {
        let n = 2000;
        let fv = crate::moments::FeatureVariances {
            variance: vec![1.0; n],
            mean: vec![0.001; n],
            second_moment: vec![0.0; n],
            docs: 10_000,
        };
        let elim = crate::elim::SafeElimination::apply(&fv.variance, 0.5, Some(1000));
        assert_eq!(elim.reduced(), 1000);
        let mut cfg = PipelineConfig {
            workers: 2,
            threads: 1,
            shard_mb: 1,
            row_cache_mb: 4,
            ..Default::default()
        };
        // dense ≈ (2+2)·8·1000² = 32 MiB; gram ≈ 0.23 + 4 MiB; disk ≥ 2 MiB
        cfg.memory_budget_mb = 64;
        assert_eq!(plan_backend(&fv, &elim, &cfg).backend, "dense");
        cfg.memory_budget_mb = 8;
        assert_eq!(plan_backend(&fv, &elim, &cfg).backend, "gram");
        cfg.memory_budget_mb = 2;
        let plan = plan_backend(&fv, &elim, &cfg);
        assert_eq!(plan.backend, "disk");
        // the logged decision line carries every footprint estimate
        let d = plan.describe();
        assert!(
            d.contains("dense≈") && d.contains("gram≈") && d.contains("budget=2 MiB"),
            "{d}"
        );
        // a budget below even the disk floor still resolves (to disk)
        cfg.memory_budget_mb = 1;
        let floor = plan_backend(&fv, &elim, &cfg);
        assert_eq!(floor.backend, "disk");
        assert!(floor.reason.contains("nothing fits"), "{}", floor.reason);
        // unlimited budget keeps the historical default
        cfg.memory_budget_mb = 0;
        assert_eq!(plan_backend(&fv, &elim, &cfg).backend, "dense");
        // xla pins dense even under a tight budget
        cfg.memory_budget_mb = 2;
        cfg.engine = "xla".into();
        let p = plan_backend(&fv, &elim, &cfg);
        assert_eq!(p.backend, "dense");
        assert!(p.reason.contains("xla"), "{}", p.reason);
    }

    #[test]
    fn disk_row_cache_budget_resolution() {
        let mut cfg = PipelineConfig {
            threads: 1,
            shard_mb: 2,
            row_cache_mb: 64,
            ..Default::default()
        };
        // no budget: the plain row-cache default applies
        cfg.memory_budget_mb = 0;
        assert_eq!(disk_row_cache_mb(&cfg, 0), 64);
        // budget minus one shard wave ((1+1)·2 MiB)
        cfg.memory_budget_mb = 100;
        assert_eq!(disk_row_cache_mb(&cfg, 0), 96);
        // an oversized single-column shard (5 MiB) prices the wave at
        // its actual size, not the configured shard_mb
        assert_eq!(disk_row_cache_mb(&cfg, 5 << 20), 90);
        // tight budgets degrade to an uncached (still correct) operator
        cfg.memory_budget_mb = 3;
        assert_eq!(disk_row_cache_mb(&cfg, 0), 0);
    }

    #[test]
    fn choose_elimination_respects_cap() {
        let fv = crate::moments::FeatureVariances {
            variance: (0..1000).map(|i| 1.0 / (1.0 + i as f64)).collect(),
            mean: vec![0.0; 1000],
            second_moment: vec![0.0; 1000],
            docs: 10,
        };
        let (elim, capped) = choose_elimination(&fv, 5, 50);
        assert!(elim.reduced() <= 50);
        assert!(!capped || elim.reduced() == 50);
    }

    #[test]
    fn pipeline_run_matches_staged_session_bitwise() {
        let cfg = tiny_config();
        let report = Pipeline::new(cfg.clone()).run().unwrap();
        let mut session = Session::from_config(cfg.clone()).unwrap();
        session.stream().unwrap();
        session.eliminate(cfg.target_card).unwrap();
        session.reduce().unwrap();
        let fit = session.fit(LambdaSpec::from_config(&cfg), cfg.num_pcs).unwrap();
        assert_eq!(report.components.len(), fit.components.len());
        for (a, b) in report.components.iter().zip(&fit.components) {
            assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
            assert_eq!(a.phi.to_bits(), b.phi.to_bits());
            assert_eq!(a.pc.support, b.pc.support);
            for (x, y) in a.pc.vector.iter().zip(&b.pc.vector) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(report.topic_table, fit.topic_table);
        assert_eq!(report.model, fit.model);
    }
}
