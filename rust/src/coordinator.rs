//! The end-to-end pipeline — the paper's §4 workflow as one coordinator:
//!
//! ```text
//! corpus (file or synthetic)
//!   → streamed variance pass (sharded workers, backpressure)      stream/moments
//!   → safe feature elimination at λ̂ for the target cardinality    elim
//!   → streamed reduced-covariance pass                            cov
//!   → λ-search + BCA solve (native or XLA engine)                 solver/engine
//!   → deflate, repeat for num_pcs components                      solver::deflate
//!   → topic table + metrics                                       report
//!   → model artifact (original-space PCs + norm stats)            model
//! ```
//!
//! Deflation note: components after the first are extracted from the same
//! reduced covariance operator, re-solving after stacking earlier PCs as
//! rank-K corrections ([`DeflatedCov`]) — the paper's "top 5 sparse
//! principal components" workflow, without destructive dense edits. The
//! initial λ̂ for *elimination* is chosen from the variance profile so the
//! reduced problem comfortably contains a cardinality-`target` solution
//! (`max_reduced` caps it; the cap is reported when it binds).
//!
//! Covariance backend (`cov.backend`): `"dense"` streams the reduced
//! n̂ × n̂ matrix exactly as before (every solve bitwise the historical
//! pipeline; components after the first agree to ~1e-9 because deflation
//! reassociates the destructive updates' arithmetic);
//! `"gram"` streams the reduced sparse term matrix instead and serves Σ
//! implicitly through [`crate::covop::GramCov`] — O(nnz) memory plus a
//! bounded row cache, so n̂ can reach tens of thousands; `"disk"`
//! persists that matrix to the shard cache once and streams it through
//! [`crate::cov_disk::DiskGramCov`] under `[memory] budget_mb` (solves
//! bitwise-identical to `"gram"`); `"auto"` lets [`plan_backend`] — the
//! memory-budget planner — pick from variance-pass footprint estimates,
//! logging the numbers behind the decision.

use std::path::{Path, PathBuf};

use crate::config::PipelineConfig;
use crate::corpus::{CorpusSpec, SynthCorpus};
use crate::cov::{covariance_pass, gram_pass, reduced_csr_pass};
use crate::cov_disk::DiskGramCov;
use crate::covop::{CovOp, DenseCov, MaskedCov};
use crate::data::shardcache::{self, ShardCacheKey};
use crate::data::Vocab;
use crate::elim::{lambda_for_survivors, SafeElimination};
use crate::engine::{Engine, NativeEngine};
#[cfg(feature = "xla")]
use crate::engine::XlaEngine;
use crate::moments::FeatureVariances;
use crate::solver::bca::BcaOptions;
use crate::solver::deflate::{DeflatedCov, Scheme};
use crate::solver::extract::SparsePc;
use crate::solver::lambda::{search, LambdaSearchOptions};
use crate::stream::{variance_pass, FileSource, StreamOptions, SynthSource};
use crate::util::timer::{Profiler, Timer};

/// One extracted component with its reporting metadata.
#[derive(Clone, Debug)]
pub struct ComponentReport {
    /// The sparse PC in *reduced* coordinates.
    pub pc: SparsePc,
    /// λ chosen by the cardinality search.
    pub lambda: f64,
    /// Problem-(1) objective.
    pub phi: f64,
    /// Explained variance on the (deflated) reduced covariance.
    pub explained_variance: f64,
    /// Words (or `wNNNNN` labels) of the support, by decreasing |loading|.
    pub words: Vec<String>,
    /// Wall seconds to find this PC (λ-search + solves).
    pub seconds: f64,
    /// Dual optimality gap (upper bound − φ), when `solver.certify` is on.
    pub certificate_gap: Option<f64>,
}

/// Full pipeline output.
#[derive(Debug)]
pub struct PipelineReport {
    /// Corpus name (preset) or input path.
    pub corpus_name: String,
    /// Documents streamed.
    pub num_docs: usize,
    /// Original vocabulary size n.
    pub vocab_size: usize,
    /// Corpus nonzeros streamed in pass 1.
    pub nnz: u64,
    /// Sorted variance profile (Fig 2 series).
    pub sorted_variances: Vec<f64>,
    /// Reduced problem size n̂ after elimination (E5 headline).
    pub reduced_size: usize,
    /// `n / n̂`.
    pub reduction_factor: f64,
    /// λ̂ the elimination ran at.
    pub elim_lambda: f64,
    /// Whether `max_reduced` bound the reduction.
    pub elim_capped: bool,
    /// One entry per extracted sparse PC.
    pub components: Vec<ComponentReport>,
    /// Second-level timing profile.
    pub profile: String,
    /// End-to-end wall seconds.
    pub total_seconds: f64,
    /// Markdown topic table (the paper's Tables 1–2 format).
    pub topic_table: String,
    /// The serving artifact: original-space sparse PCs plus the
    /// elimination map and normalization statistics (always built — it
    /// is a few KiB; written to disk when `model.save_path` is set).
    pub model: crate::model::Model,
}

/// The pipeline object: configuration + engine.
pub struct Pipeline {
    /// The full run configuration.
    pub config: PipelineConfig,
}

impl Pipeline {
    /// Wrap a validated configuration.
    pub fn new(config: PipelineConfig) -> Pipeline {
        Pipeline { config }
    }

    fn stream_opts(&self) -> StreamOptions {
        StreamOptions {
            workers: self.config.workers,
            chunk_docs: self.config.chunk_docs,
            queue_depth: self.config.queue_depth,
        }
    }

    fn make_engine(&self) -> Result<Box<dyn Engine>, String> {
        match self.config.engine.as_str() {
            "native" => Ok(Box::new(NativeEngine::new().with_threads(self.config.threads))),
            #[cfg(feature = "xla")]
            "xla" => Ok(Box::new(XlaEngine::load(Path::new(&self.config.artifacts_dir))?)),
            #[cfg(not(feature = "xla"))]
            "xla" => Err("this build has no XLA support (rebuild with --features xla)".into()),
            other => Err(format!("unknown engine '{other}'")),
        }
    }

    /// Run end-to-end. `input` resolution: configured file path, else a
    /// synthetic corpus streamed straight from the generator.
    pub fn run(&self) -> Result<PipelineReport, String> {
        let total = Timer::start();
        let mut prof = Profiler::new();
        let opts = self.stream_opts();

        // --- resolve corpus ------------------------------------------------
        let synth: Option<SynthCorpus> = if self.config.input.is_empty() {
            let spec = CorpusSpec::preset(&self.config.synth_preset)
                .ok_or_else(|| format!("unknown preset {}", self.config.synth_preset))?
                .scaled(self.config.synth_docs, self.config.synth_vocab);
            Some(SynthCorpus::new(spec, self.config.seed))
        } else {
            None
        };
        let input_path = PathBuf::from(&self.config.input);
        let vocab = match &synth {
            Some(s) => s.vocab.clone(),
            None => {
                let vp = input_path.with_extension("vocab");
                if vp.exists() {
                    Vocab::load(&vp)?
                } else {
                    Vocab::default()
                }
            }
        };
        let corpus_name = synth
            .as_ref()
            .map(|s| s.spec.name.to_string())
            .unwrap_or_else(|| input_path.display().to_string());
        crate::info!("pipeline start: corpus={corpus_name} engine={}", self.config.engine);

        // --- pass 1: variances (with optional checkpoint reuse) -------------
        // Fingerprint the corpus identity: synthetic params, or the
        // input path + its size (cheap mtime-free invalidation). Shared
        // by the variance checkpoint and the covariance shard cache.
        let identity = match &synth {
            Some(s) => format!(
                "synth:{}:{}:{}:{}",
                s.spec.name, s.spec.num_docs, s.spec.vocab_size, s.seed
            ),
            None => {
                let len = std::fs::metadata(&input_path).map(|m| m.len()).unwrap_or(0);
                format!("file:{}:{len}", input_path.display())
            }
        };
        let corpus_digest = crate::checkpoint::corpus_key(&identity);
        let cache = if self.config.cache_dir.is_empty() {
            None
        } else {
            Some((
                crate::checkpoint::path_for(Path::new(&self.config.cache_dir), corpus_digest),
                corpus_digest,
            ))
        };
        // The corpus' live feature dimension, for checkpoint validation:
        // a cached file whose key collides but whose n differs must be
        // rejected up front, not panic later inside elimination.
        let expected_n: Option<usize> = match &synth {
            Some(s) => Some(s.spec.vocab_size),
            None => crate::data::docword::DocwordReader::open(&input_path)
                .ok()
                .map(|r| r.header().vocab_size),
        };
        let cached_fv = match &cache {
            Some((path, key)) => match crate::checkpoint::load(path, *key, expected_n) {
                Ok(hit) => {
                    if hit.is_some() {
                        crate::info!("variance pass: checkpoint hit at {}", path.display());
                    }
                    hit
                }
                Err(e) => {
                    crate::warn_!("ignoring bad variance checkpoint: {e}");
                    None
                }
            },
            None => None,
        };
        let (fv, stats1) = match cached_fv {
            Some(fv) => {
                let stats = crate::stream::StreamStats {
                    docs: fv.docs,
                    ..Default::default()
                };
                (fv, stats)
            }
            None => {
                let (fv, stats) = prof.time("variance_pass", || -> Result<_, String> {
                    match &synth {
                        Some(s) => variance_pass(&mut SynthSource::new(s), opts),
                        None => {
                            let mut src = FileSource::open(&input_path)?;
                            variance_pass(&mut src, opts)
                        }
                    }
                })?;
                if let Some((path, key)) = &cache {
                    if let Err(e) = crate::checkpoint::save(path, *key, &fv) {
                        crate::warn_!("could not write variance checkpoint: {e}");
                    }
                }
                (fv, stats)
            }
        };
        crate::info!(
            "variance pass: {} docs, {} nnz in {:.2}s",
            stats1.docs,
            stats1.nnz,
            stats1.seconds
        );

        // --- safe elimination ----------------------------------------------
        let (elim, elim_capped) = prof.time("elimination", || {
            choose_elimination(&fv, self.config.target_card, self.config.max_reduced)
        });
        crate::info!(
            "safe elimination: λ={:.4e} keeps n̂={} of n={} ({}x reduction{})",
            elim.lambda,
            elim.reduced(),
            elim.original,
            elim.reduction_factor() as u64,
            if elim_capped { ", capped" } else { "" }
        );
        if elim.reduced() == 0 {
            return Err("elimination removed every feature; lower solver.target λ̂".into());
        }

        // --- memory-budget planner ------------------------------------------
        // `auto` resolves to a concrete backend from footprint estimates
        // derived off the variance pass; explicit backends pass through.
        let backend = if self.config.cov_backend == "auto" {
            let plan = plan_backend(&fv, &elim, &self.config);
            crate::info!("memory planner: {}", plan.describe());
            plan.backend
        } else {
            self.config.cov_backend.clone()
        };

        // --- pass 2: reduced covariance operator ----------------------------
        let cov: Box<dyn CovOp> = match backend.as_str() {
            "disk" => {
                let dir = if self.config.cache_dir.is_empty() {
                    // No configured dir: fall back to a stable
                    // *per-user* location under the system temp dir so
                    // the cache still reuses across runs without two
                    // users fighting over one world-writable path.
                    let user = std::env::var("USER")
                        .or_else(|_| std::env::var("USERNAME"))
                        .unwrap_or_else(|_| "default".into());
                    std::env::temp_dir().join(format!("lsspca_shards_{user}"))
                } else {
                    PathBuf::from(&self.config.cache_dir)
                };
                // The fallback dir may sit under a shared tmp; keep it
                // private to this user where the platform supports it.
                if self.config.cache_dir.is_empty() {
                    make_private_dir(&dir);
                }
                let key = ShardCacheKey {
                    corpus_digest,
                    elim_digest: shardcache::elim_digest(&elim),
                };
                // A hit is only a hit once every shard verifies: the
                // operator cannot return errors mid-solve, so a corrupt
                // or truncated shard must be caught (and the cache
                // rebuilt) here, not hours into BCA.
                let opened = match shardcache::open(&dir, &key) {
                    Ok(Some(man)) => {
                        match prof.time("shard_verify", || {
                            shardcache::verify_shards(&dir, &man, self.config.threads)
                        }) {
                            Ok(()) => {
                                crate::info!(
                                    "shard cache hit: {} shards, nnz={} at {}",
                                    man.shards.len(),
                                    man.nnz,
                                    dir.display()
                                );
                                Some(man)
                            }
                            Err(e) => {
                                crate::warn_!("rebuilding shard cache: {e}");
                                None
                            }
                        }
                    }
                    Ok(None) => None,
                    Err(e) => {
                        crate::warn_!("rebuilding shard cache: {e}");
                        None
                    }
                };
                let man = match opened {
                    Some(man) => man,
                    None => {
                        let (csr, stats2) = prof.time("gram_pass", || match &synth {
                            Some(s) => reduced_csr_pass(&mut SynthSource::new(s), &elim, opts),
                            None => {
                                let mut src = FileSource::open(&input_path)?;
                                reduced_csr_pass(&mut src, &elim, opts)
                            }
                        })?;
                        let man = prof.time("shard_write", || {
                            shardcache::write(
                                &dir,
                                &key,
                                &csr,
                                stats2.docs,
                                self.config.shard_mb * 1024 * 1024,
                            )
                        })?;
                        crate::info!(
                            "shard cache written: {} shards, nnz={} at {}",
                            man.shards.len(),
                            man.nnz,
                            dir.display()
                        );
                        man
                    }
                };
                // Cache sized against the *actual* decode wave: an
                // oversized single-column shard shrinks the row cache
                // rather than silently blowing the budget.
                let cache_mb = disk_row_cache_mb(&self.config, man.max_shard_bytes());
                let disk = DiskGramCov::new(&dir, man, cache_mb, self.config.threads);
                crate::info!(
                    "disk covariance backend: row cache {} rows ≤ {} MiB, {} worker threads",
                    disk.cache_capacity_rows(),
                    cache_mb,
                    crate::util::parallel::resolve_threads(self.config.threads)
                );
                Box::new(disk)
            }
            "gram" => {
                let (gram, _stats2) = prof.time("gram_pass", || match &synth {
                    Some(s) => {
                        gram_pass(&mut SynthSource::new(s), &elim, opts, self.config.row_cache_mb)
                    }
                    None => {
                        let mut src = FileSource::open(&input_path)?;
                        gram_pass(&mut src, &elim, opts, self.config.row_cache_mb)
                    }
                })?;
                crate::info!(
                    "gram pass: reduced term matrix nnz={} (row cache {} rows ≤ {} MiB)",
                    gram.nnz(),
                    gram.cache_capacity_rows(),
                    self.config.row_cache_mb
                );
                Box::new(gram)
            }
            _ => {
                let (cov, _stats2) = prof.time("covariance_pass", || match &synth {
                    Some(s) => covariance_pass(&mut SynthSource::new(s), &elim, opts),
                    None => {
                        let mut src = FileSource::open(&input_path)?;
                        covariance_pass(&mut src, &elim, opts)
                    }
                })?;
                Box::new(DenseCov::new(cov))
            }
        };

        // --- solve: λ-search + BCA + rank-K deflation ------------------------
        let mut engine = self.make_engine()?;
        let scheme = Scheme::parse(&self.config.deflation).ok_or("bad deflation scheme")?;
        let mut defl = DeflatedCov::new(cov.as_ref());
        let mut components = Vec::new();
        for k in 0..self.config.num_pcs {
            let t = Timer::start();
            let bca = BcaOptions {
                max_sweeps: self.config.bca_sweeps,
                epsilon: self.config.epsilon,
                tol: 1e-7,
                // The pipeline never reads the per-sweep history, and on
                // the gram backend each history point costs a full pass
                // of Σ-row gathers (frob_with) per sweep.
                track_history: false,
                ..Default::default()
            };
            // Parallel λ-search. The probe schedule comes from config —
            // never derived from the thread count — so the pipeline's
            // numerical results are identical on every machine and for
            // every `threads` setting; threads only change wall time.
            // The default (1) is classic bisection, the best per-eval
            // bracketing for serial runs.
            let sopts = LambdaSearchOptions {
                target_card: self.config.target_card,
                slack: self.config.card_slack,
                bca,
                probes_per_round: self.config.lambda_probes,
                threads: self.config.threads,
                ..Default::default()
            };
            let res = prof.time("lambda_search+bca", || {
                search_with_engine(&mut *engine, &defl, &sopts)
            })?;
            let words: Vec<String> = res
                .pc
                .support
                .iter()
                .map(|&r| vocab.word(elim.kept[r]))
                .collect();
            crate::info!(
                "PC {}: card={} λ={:.4} φ={:.4} [{}] in {:.2}s",
                k + 1,
                res.pc.cardinality(),
                res.lambda,
                res.solution.phi,
                words.join(", "),
                t.secs()
            );
            let explained = defl.quad_form(&res.pc.vector);
            let certificate_gap = if self.config.certify {
                let cert = prof.time("certificate", || {
                    // certify on the survivors of res.lambda (the solve
                    // space); the eliminated coordinates are provably zero.
                    // The certificate's eigendecompositions need an
                    // explicit matrix, so the survivor submatrix is
                    // materialized here (small: the solve space).
                    let diags: Vec<f64> = (0..defl.n()).map(|i| defl.diag(i)).collect();
                    let sub_elim = crate::elim::SafeElimination::apply(&diags, res.lambda, None);
                    let sub = defl.materialize(&sub_elim.kept);
                    crate::solver::certificate::certify(&sub, &res.solution.z, res.lambda)
                });
                crate::info!(
                    "PC {} certificate: φ={:.4} ≤ {:.4} (gap {:.2e})",
                    k + 1,
                    cert.primal,
                    cert.upper_bound,
                    cert.gap
                );
                Some(cert.gap)
            } else {
                None
            };
            prof.time("deflation", || defl.push(scheme, &res.pc.vector));
            components.push(ComponentReport {
                lambda: res.lambda,
                phi: res.solution.phi,
                explained_variance: explained,
                words,
                seconds: t.secs(),
                pc: res.pc,
                certificate_gap,
            });
        }

        let topic_table = crate::report::topic_table(
            &components.iter().map(|c| c.pc.clone()).collect::<Vec<_>>(),
            &vocab,
            Some(&elim.kept),
        );

        // --- model artifact: the hand-off to `score` / `serve` ---------------
        let n_orig = fv.variance.len();
        let model = crate::model::Model {
            corpus_name: corpus_name.clone(),
            num_docs: stats1.docs,
            n_features: n_orig,
            vocab_hash: crate::model::vocab_hash(&vocab),
            seed: self.config.seed,
            elim_lambda: elim.lambda,
            kept: elim.kept.clone(),
            kept_means: elim.kept.iter().map(|&i| fv.mean[i]).collect(),
            kept_stds: elim.kept.iter().map(|&i| fv.variance[i].sqrt()).collect(),
            kept_words: elim.kept.iter().map(|&i| vocab.word(i)).collect(),
            pcs: components
                .iter()
                .map(|c| crate::model::ModelPc {
                    lambda: c.lambda,
                    phi: c.phi,
                    explained_variance: c.explained_variance,
                    loadings: c.pc.mapped(&elim.kept, n_orig).loadings(),
                })
                .collect(),
        };
        if !self.config.save_model.is_empty() {
            model.save(Path::new(&self.config.save_model))?;
            crate::info!("model artifact written to {}", self.config.save_model);
        }

        Ok(PipelineReport {
            corpus_name,
            num_docs: stats1.docs as usize,
            vocab_size: fv.variance.len(),
            nnz: stats1.nnz,
            sorted_variances: fv.sorted_variances(),
            reduced_size: elim.reduced(),
            reduction_factor: elim.reduction_factor(),
            elim_lambda: elim.lambda,
            elim_capped,
            components,
            profile: prof.report(),
            total_seconds: total.secs(),
            topic_table,
            model,
        })
    }
}

/// Choose the elimination λ̂ for a target PC cardinality: keep a working
/// set comfortably larger than the target (the λ-search then operates
/// inside it), capped at `max_reduced`. Returns the elimination and
/// whether the cap bound.
pub fn choose_elimination(
    fv: &FeatureVariances,
    target_card: usize,
    max_reduced: usize,
) -> (SafeElimination, bool) {
    // Working set ~ 40× the target cardinality mirrors the paper's
    // observation (target 5 → n̂ ≤ ~500 on NYTimes within a ~100k vocab).
    let want = (target_card * 40).min(max_reduced).max(target_card);
    let lam = lambda_for_survivors(&fv.variance, want);
    let elim = SafeElimination::from_variances(fv, lam, Some(max_reduced));
    let capped = elim.capped(&fv.variance);
    (elim, capped)
}

/// Outcome of the memory-budget planner: the chosen backend and the
/// footprint estimates (in bytes) the decision was based on.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    /// Reduced problem size n̂ the estimates assume.
    pub nhat: usize,
    /// Estimated peak resident bytes of the dense backend (streaming
    /// assembly holds one n̂ × n̂ partial per worker, plus Σ itself and
    /// the solver iterate).
    pub dense_bytes: u64,
    /// Estimated resident bytes of the in-memory gram backend (CSR +
    /// CSC of the reduced matrix, bounded above via the variance-pass
    /// per-feature counts, plus the row cache).
    pub gram_bytes: u64,
    /// Resident floor of the disk backend (one streaming wave of shards;
    /// the row cache then takes whatever budget remains).
    pub disk_bytes: u64,
    /// The configured budget in bytes (0 = unlimited).
    pub budget_bytes: u64,
    /// The backend the planner picked: "dense", "gram" or "disk".
    pub backend: String,
    /// One-line human reason for the choice.
    pub reason: String,
}

impl MemoryPlan {
    /// Render the full decision — estimates and reason — for the log.
    pub fn describe(&self) -> String {
        let mb = |b: u64| (b as f64 / (1024.0 * 1024.0)).ceil() as u64;
        format!(
            "n̂={} budget={} dense≈{} MiB gram≈{} MiB disk≥{} MiB → backend={} ({})",
            self.nhat,
            if self.budget_bytes == 0 {
                "unlimited".to_string()
            } else {
                format!("{} MiB", mb(self.budget_bytes))
            },
            mb(self.dense_bytes),
            mb(self.gram_bytes),
            mb(self.disk_bytes),
            self.backend,
            self.reason
        )
    }
}

/// The memory-budget planner behind `[cov] backend = "auto"`: estimate
/// the dense / gram / disk covariance footprints from the variance-pass
/// statistics and pick the cheapest-to-serve backend that fits
/// `[memory] budget_mb`.
///
/// Estimates (all deliberately upper bounds — the planner must never
/// pick a backend that then blows the budget):
///
/// - **dense**: `(workers + 2) · 8n̂²` — the streaming assembly holds one
///   n̂ × n̂ partial accumulator per worker, then Σ plus the solver
///   iterate X stay resident.
/// - **gram**: `24 · nnẑ + row_cache` where `nnẑ = Σ_{j kept}
///   min(m, m·μ_j)` bounds the reduced matrix's nonzeros via the
///   variance-pass per-feature means (counts ≥ 1 ⇒ doc-frequency ≤
///   total count), and 24 bytes/nnz covers the CSR + CSC pair.
/// - **disk**: `(threads + 1) · max(shard_mb, largest column) +
///   8·rows` — one decode wave of shards plus the dense `A·x` scratch
///   every matvec/quadratic form holds (one f64 per reduced row,
///   bounded above by `min(m, nnẑ)` since each reduced row has ≥ 1
///   nonzero). A column whose payload alone exceeds `shard_mb` becomes
///   one oversized shard (`plan_shards` never splits a column), so the
///   wave term uses the larger of the configured shard size and the
///   biggest kept column's estimated bytes. The Σ-row cache is then
///   *sized from* the remaining budget rather than estimated (see
///   [`disk_row_cache_mb`]).
///
/// With no budget configured (`budget_mb = 0`) the planner keeps the
/// historical default, dense; under the XLA engine it pins dense
/// outright (the artifacts need an explicit matrix).
pub fn plan_backend(
    fv: &FeatureVariances,
    elim: &SafeElimination,
    cfg: &PipelineConfig,
) -> MemoryPlan {
    const MIB: u64 = 1024 * 1024;
    let nhat = elim.reduced() as u64;
    let m = fv.docs;
    let dense_bytes = (cfg.workers as u64 + 2) * 8 * nhat * nhat;
    let col_nnz_est = |j: usize| (fv.mean[j] * m as f64).min(m as f64).max(0.0);
    let nnz_est: f64 = elim.kept.iter().map(|&j| col_nnz_est(j)).sum();
    let gram_bytes = (24.0 * nnz_est) as u64 + cfg.row_cache_mb as u64 * MIB;
    let wave = crate::util::parallel::resolve_threads(cfg.threads) as u64 + 1;
    // A single column larger than shard_mb becomes one oversized shard,
    // so the wave term must use the larger of the two.
    let max_col_bytes = elim
        .kept
        .iter()
        .map(|&j| (12.0 * col_nnz_est(j)) as u64)
        .max()
        .unwrap_or(0);
    // Every matvec/quad form also holds one dense A·x scratch of one
    // f64 per reduced row (rows ≤ min(m, nnẑ): each row has ≥ 1 nnz).
    let ax_bytes = 8 * (m.min(nnz_est as u64));
    let disk_bytes = wave * (cfg.shard_mb as u64 * MIB).max(max_col_bytes) + ax_bytes;
    let budget_bytes = cfg.memory_budget_mb as u64 * MIB;
    let (backend, reason) = if cfg.engine == "xla" {
        ("dense", "xla engine needs an explicit dense Σ".to_string())
    } else if budget_bytes == 0 {
        ("dense", "no memory budget configured; keeping the default".to_string())
    } else if dense_bytes <= budget_bytes {
        ("dense", "dense fits the budget".to_string())
    } else if gram_bytes <= budget_bytes {
        ("gram", "dense exceeds the budget, implicit gram fits".to_string())
    } else if disk_bytes <= budget_bytes {
        ("disk", "only the out-of-core backend fits the budget".to_string())
    } else {
        (
            "disk",
            format!(
                "nothing fits the budget (disk floor ≈ {} MiB); \
                 falling back to disk, the smallest-footprint backend",
                disk_bytes.div_ceil(MIB)
            ),
        )
    };
    MemoryPlan {
        nhat: elim.reduced(),
        dense_bytes,
        gram_bytes,
        disk_bytes,
        budget_bytes,
        backend: backend.to_string(),
        reason,
    }
}

/// Σ-row cache budget (MiB) for the disk backend: whatever remains of
/// `[memory] budget_mb` after one streaming wave of shards, or the
/// `row_cache_mb` default when no budget is configured. The wave is
/// priced at the **actual** largest shard (`max_shard_bytes`, from the
/// manifest) rather than the configured `shard_mb`, because a column
/// bigger than the configured budget becomes one oversized shard. May
/// return 0 — the cache never changes a value, only wall time.
pub fn disk_row_cache_mb(cfg: &PipelineConfig, max_shard_bytes: u64) -> usize {
    if cfg.memory_budget_mb == 0 {
        return cfg.row_cache_mb;
    }
    const MIB: u64 = 1024 * 1024;
    let wave = crate::util::parallel::resolve_threads(cfg.threads) as u64 + 1;
    let shard = (cfg.shard_mb as u64 * MIB).max(max_shard_bytes);
    let reserve_mb = (wave * shard).div_ceil(MIB) as usize;
    cfg.memory_budget_mb.saturating_sub(reserve_mb)
}

/// Create `dir` (and parents) with user-only permissions where the
/// platform supports it — the default shard-cache location sits under
/// a shared temp directory. Errors are deferred to the first write.
fn make_private_dir(dir: &Path) {
    #[cfg(unix)]
    {
        use std::os::unix::fs::DirBuilderExt;
        let _ = std::fs::DirBuilder::new().recursive(true).mode(0o700).create(dir);
    }
    #[cfg(not(unix))]
    {
        let _ = std::fs::create_dir_all(dir);
    }
}

/// λ-search where the inner solves run on an [`Engine`].
pub fn search_with_engine(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    opts: &LambdaSearchOptions,
) -> Result<crate::solver::lambda::LambdaSearchResult, String> {
    match engine.name() {
        // The native fast path uses the allocation-free direct solver.
        "native" => Ok(search(sigma, opts)),
        _ => {
            // Engine-generic path: replicate the search but solve via engine.
            let mut eopts = *opts;
            eopts.bca.track_history = false;
            engine_search(engine, sigma, &eopts)
        }
    }
}

fn engine_search(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    opts: &LambdaSearchOptions,
) -> Result<crate::solver::lambda::LambdaSearchResult, String> {
    use crate::solver::extract::leading_sparse_pc;
    use crate::solver::lambda::{LambdaEval, LambdaSearchResult};
    let n = sigma.n();
    let max_diag = (0..n).map(|i| sigma.diag(i)).fold(0.0f64, f64::max);
    let (mut lo, mut hi) = (0.0f64, max_diag * 0.999);
    let mut lambda = 0.5 * hi;
    let mut trace = Vec::new();
    let mut best: Option<(f64, crate::solver::bca::BcaSolution, SparsePc)> = None;
    let mut best_key = (usize::MAX, f64::NEG_INFINITY);
    let diags: Vec<f64> = (0..n).map(|i| sigma.diag(i)).collect();
    for evals in 0..opts.max_evals {
        // Per-probe safe elimination (Thm 2.1), mirroring the native
        // search: solve on the masked survivor view and lift back.
        let elim = crate::elim::SafeElimination::apply(&diags, lambda, None);
        let use_mask =
            opts.per_lambda_elim && elim.reduced() != n && elim.reduced() != 0;
        let (sol, pc) = if !use_mask {
            let sol = crate::engine::bca_solve(engine, sigma, lambda, &opts.bca)?;
            let pc = leading_sparse_pc(&sol.z, opts.extract_tol);
            (sol, pc)
        } else {
            let sub = MaskedCov::new(sigma, elim.kept.clone());
            let sol = crate::engine::bca_solve(engine, &sub, lambda, &opts.bca)?;
            let pc = leading_sparse_pc(&sol.z, opts.extract_tol).mapped(&elim.kept, n);
            (sol, pc)
        };
        let card = pc.cardinality();
        trace.push(LambdaEval { lambda, cardinality: card, phi: sol.phi });
        let key = (card.abs_diff(opts.target_card), sol.phi);
        if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 > best_key.1) {
            best_key = key;
            best = Some((lambda, sol, pc));
        }
        let dist = card.abs_diff(opts.target_card);
        if dist == 0 || (dist <= opts.slack && evals + 1 >= opts.max_evals / 2) {
            break;
        }
        if card > opts.target_card {
            lo = lambda;
        } else {
            hi = lambda;
        }
        lambda = 0.5 * (lo + hi);
        if (hi - lo) < 1e-12 * (1.0 + max_diag) {
            break;
        }
    }
    let (lambda, solution, pc) = best.ok_or("no evaluations")?;
    let hit_target = pc.cardinality().abs_diff(opts.target_card) <= opts.slack;
    Ok(LambdaSearchResult { lambda, solution, pc, trace, hit_target })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> PipelineConfig {
        PipelineConfig {
            synth_preset: "nytimes".into(),
            synth_docs: 800,
            synth_vocab: 3000,
            workers: 2,
            chunk_docs: 128,
            num_pcs: 3,
            target_card: 5,
            card_slack: 2,
            max_reduced: 64,
            bca_sweeps: 6,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_tiny_nytimes() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        assert_eq!(report.num_docs, 800);
        assert!(report.reduced_size > 0 && report.reduced_size <= 64);
        assert!(report.reduction_factor > 10.0, "reduction {}", report.reduction_factor);
        assert_eq!(report.components.len(), 3);
        for c in &report.components {
            assert!(c.pc.cardinality() >= 1);
            assert!(c.pc.cardinality() <= 5 + 4, "card {}", c.pc.cardinality());
            assert!(!c.words.is_empty());
        }
        // topic table mentions at least one planted word from Table 1
        let planted = ["million", "percent", "point", "play", "official", "president", "school"];
        assert!(
            planted.iter().any(|w| report.topic_table.contains(w)),
            "topic table:\n{}",
            report.topic_table
        );
        // Fig 2 series is sorted descending
        assert!(report
            .sorted_variances
            .windows(2)
            .all(|w| w[0] >= w[1]));
    }

    #[test]
    fn first_pc_recovers_a_planted_topic() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        // The strongest PC should consist mostly of words from ONE topic.
        let spec = CorpusSpec::nytimes();
        let first = &report.components[0];
        let mut best_overlap = 0usize;
        for t in &spec.topics {
            let overlap = first
                .words
                .iter()
                .filter(|w| t.words.contains(&w.as_str()))
                .count();
            best_overlap = best_overlap.max(overlap);
        }
        assert!(
            best_overlap * 2 >= first.words.len(),
            "PC1 words {:?} do not concentrate on one topic",
            first.words
        );
    }

    #[test]
    fn report_model_is_consistent_with_components() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        let m = &report.model;
        m.validate().unwrap();
        assert_eq!(m.n_features, report.vocab_size);
        assert_eq!(m.kept.len(), report.reduced_size);
        assert_eq!(m.pcs.len(), report.components.len());
        assert_eq!(m.num_docs as usize, report.num_docs);
        for (c, pc) in report.components.iter().zip(&m.pcs) {
            assert_eq!(pc.loadings.len(), c.pc.cardinality());
            // original-space loadings are the reduced PC pushed through
            // the kept map, bit for bit, in the same support order
            for (&(orig, w), &r) in pc.loadings.iter().zip(&c.pc.support) {
                assert_eq!(orig, m.kept[r]);
                assert_eq!(w.to_bits(), c.pc.vector[r].to_bits());
            }
            assert_eq!(pc.lambda, c.lambda);
        }
        // the model's top word per PC matches the reported word list
        for (c, pc) in report.components.iter().zip(&m.pcs) {
            assert_eq!(m.word_of(pc.loadings[0].0), c.words[0]);
        }
    }

    #[test]
    fn memory_planner_picks_backend_by_budget() {
        let n = 2000;
        let fv = crate::moments::FeatureVariances {
            variance: vec![1.0; n],
            mean: vec![0.001; n],
            second_moment: vec![0.0; n],
            docs: 10_000,
        };
        let elim = crate::elim::SafeElimination::apply(&fv.variance, 0.5, Some(1000));
        assert_eq!(elim.reduced(), 1000);
        let mut cfg = PipelineConfig {
            workers: 2,
            threads: 1,
            shard_mb: 1,
            row_cache_mb: 4,
            ..Default::default()
        };
        // dense ≈ (2+2)·8·1000² = 32 MiB; gram ≈ 0.23 + 4 MiB; disk ≥ 2 MiB
        cfg.memory_budget_mb = 64;
        assert_eq!(plan_backend(&fv, &elim, &cfg).backend, "dense");
        cfg.memory_budget_mb = 8;
        assert_eq!(plan_backend(&fv, &elim, &cfg).backend, "gram");
        cfg.memory_budget_mb = 2;
        let plan = plan_backend(&fv, &elim, &cfg);
        assert_eq!(plan.backend, "disk");
        // the logged decision line carries every footprint estimate
        let d = plan.describe();
        assert!(
            d.contains("dense≈") && d.contains("gram≈") && d.contains("budget=2 MiB"),
            "{d}"
        );
        // a budget below even the disk floor still resolves (to disk)
        cfg.memory_budget_mb = 1;
        let floor = plan_backend(&fv, &elim, &cfg);
        assert_eq!(floor.backend, "disk");
        assert!(floor.reason.contains("nothing fits"), "{}", floor.reason);
        // unlimited budget keeps the historical default
        cfg.memory_budget_mb = 0;
        assert_eq!(plan_backend(&fv, &elim, &cfg).backend, "dense");
        // xla pins dense even under a tight budget
        cfg.memory_budget_mb = 2;
        cfg.engine = "xla".into();
        let p = plan_backend(&fv, &elim, &cfg);
        assert_eq!(p.backend, "dense");
        assert!(p.reason.contains("xla"), "{}", p.reason);
    }

    #[test]
    fn disk_row_cache_budget_resolution() {
        let mut cfg = PipelineConfig {
            threads: 1,
            shard_mb: 2,
            row_cache_mb: 64,
            ..Default::default()
        };
        // no budget: the plain row-cache default applies
        cfg.memory_budget_mb = 0;
        assert_eq!(disk_row_cache_mb(&cfg, 0), 64);
        // budget minus one shard wave ((1+1)·2 MiB)
        cfg.memory_budget_mb = 100;
        assert_eq!(disk_row_cache_mb(&cfg, 0), 96);
        // an oversized single-column shard (5 MiB) prices the wave at
        // its actual size, not the configured shard_mb
        assert_eq!(disk_row_cache_mb(&cfg, 5 << 20), 90);
        // tight budgets degrade to an uncached (still correct) operator
        cfg.memory_budget_mb = 3;
        assert_eq!(disk_row_cache_mb(&cfg, 0), 0);
    }

    #[test]
    fn choose_elimination_respects_cap() {
        let fv = crate::moments::FeatureVariances {
            variance: (0..1000).map(|i| 1.0 / (1.0 + i as f64)).collect(),
            mean: vec![0.0; 1000],
            second_moment: vec![0.0; 1000],
            docs: 10,
        };
        let (elim, capped) = choose_elimination(&fv, 5, 50);
        assert!(elim.reduced() <= 50);
        assert!(!capped || elim.reduced() == 50);
    }
}
