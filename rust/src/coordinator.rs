//! The end-to-end pipeline — the paper's §4 workflow as one coordinator:
//!
//! ```text
//! corpus (file or synthetic)
//!   → streamed variance pass (sharded workers, backpressure)      stream/moments
//!   → safe feature elimination at λ̂ for the target cardinality    elim
//!   → streamed reduced-covariance pass                            cov
//!   → λ-search + BCA solve (native or XLA engine)                 solver/engine
//!   → deflate, repeat for num_pcs components                      solver::deflate
//!   → topic table + metrics                                       report
//!   → model artifact (original-space PCs + norm stats)            model
//! ```
//!
//! Deflation note: components after the first are extracted from the same
//! reduced covariance operator, re-solving after stacking earlier PCs as
//! rank-K corrections ([`DeflatedCov`]) — the paper's "top 5 sparse
//! principal components" workflow, without destructive dense edits. The
//! initial λ̂ for *elimination* is chosen from the variance profile so the
//! reduced problem comfortably contains a cardinality-`target` solution
//! (`max_reduced` caps it; the cap is reported when it binds).
//!
//! Covariance backend (`cov.backend`): `"dense"` streams the reduced
//! n̂ × n̂ matrix exactly as before (every solve bitwise the historical
//! pipeline; components after the first agree to ~1e-9 because deflation
//! reassociates the destructive updates' arithmetic);
//! `"gram"` streams the reduced sparse term matrix instead and serves Σ
//! implicitly through [`crate::covop::GramCov`] — O(nnz) memory plus a
//! bounded row cache, so n̂ can reach tens of thousands.

use std::path::{Path, PathBuf};

use crate::config::PipelineConfig;
use crate::corpus::{CorpusSpec, SynthCorpus};
use crate::cov::{covariance_pass, gram_pass};
use crate::covop::{CovOp, DenseCov, MaskedCov};
use crate::data::Vocab;
use crate::elim::{lambda_for_survivors, SafeElimination};
use crate::engine::{Engine, NativeEngine};
#[cfg(feature = "xla")]
use crate::engine::XlaEngine;
use crate::moments::FeatureVariances;
use crate::solver::bca::BcaOptions;
use crate::solver::deflate::{DeflatedCov, Scheme};
use crate::solver::extract::SparsePc;
use crate::solver::lambda::{search, LambdaSearchOptions};
use crate::stream::{variance_pass, FileSource, StreamOptions, SynthSource};
use crate::util::timer::{Profiler, Timer};

/// One extracted component with its reporting metadata.
#[derive(Clone, Debug)]
pub struct ComponentReport {
    /// The sparse PC in *reduced* coordinates.
    pub pc: SparsePc,
    /// λ chosen by the cardinality search.
    pub lambda: f64,
    /// Problem-(1) objective.
    pub phi: f64,
    /// Explained variance on the (deflated) reduced covariance.
    pub explained_variance: f64,
    /// Words (or `wNNNNN` labels) of the support, by decreasing |loading|.
    pub words: Vec<String>,
    /// Wall seconds to find this PC (λ-search + solves).
    pub seconds: f64,
    /// Dual optimality gap (upper bound − φ), when `solver.certify` is on.
    pub certificate_gap: Option<f64>,
}

/// Full pipeline output.
#[derive(Debug)]
pub struct PipelineReport {
    pub corpus_name: String,
    pub num_docs: usize,
    pub vocab_size: usize,
    pub nnz: u64,
    /// Sorted variance profile (Fig 2 series).
    pub sorted_variances: Vec<f64>,
    /// Elimination metadata (E5 headline).
    pub reduced_size: usize,
    pub reduction_factor: f64,
    pub elim_lambda: f64,
    pub elim_capped: bool,
    pub components: Vec<ComponentReport>,
    /// Second-level timing profile.
    pub profile: String,
    pub total_seconds: f64,
    /// Markdown topic table (the paper's Tables 1–2 format).
    pub topic_table: String,
    /// The serving artifact: original-space sparse PCs plus the
    /// elimination map and normalization statistics (always built — it
    /// is a few KiB; written to disk when `model.save_path` is set).
    pub model: crate::model::Model,
}

/// The pipeline object: configuration + engine.
pub struct Pipeline {
    pub config: PipelineConfig,
}

impl Pipeline {
    pub fn new(config: PipelineConfig) -> Pipeline {
        Pipeline { config }
    }

    fn stream_opts(&self) -> StreamOptions {
        StreamOptions {
            workers: self.config.workers,
            chunk_docs: self.config.chunk_docs,
            queue_depth: self.config.queue_depth,
        }
    }

    fn make_engine(&self) -> Result<Box<dyn Engine>, String> {
        match self.config.engine.as_str() {
            "native" => Ok(Box::new(NativeEngine::new().with_threads(self.config.threads))),
            #[cfg(feature = "xla")]
            "xla" => Ok(Box::new(XlaEngine::load(Path::new(&self.config.artifacts_dir))?)),
            #[cfg(not(feature = "xla"))]
            "xla" => Err("this build has no XLA support (rebuild with --features xla)".into()),
            other => Err(format!("unknown engine '{other}'")),
        }
    }

    /// Run end-to-end. `input` resolution: configured file path, else a
    /// synthetic corpus streamed straight from the generator.
    pub fn run(&self) -> Result<PipelineReport, String> {
        let total = Timer::start();
        let mut prof = Profiler::new();
        let opts = self.stream_opts();

        // --- resolve corpus ------------------------------------------------
        let synth: Option<SynthCorpus> = if self.config.input.is_empty() {
            let spec = CorpusSpec::preset(&self.config.synth_preset)
                .ok_or_else(|| format!("unknown preset {}", self.config.synth_preset))?
                .scaled(self.config.synth_docs, self.config.synth_vocab);
            Some(SynthCorpus::new(spec, self.config.seed))
        } else {
            None
        };
        let input_path = PathBuf::from(&self.config.input);
        let vocab = match &synth {
            Some(s) => s.vocab.clone(),
            None => {
                let vp = input_path.with_extension("vocab");
                if vp.exists() {
                    Vocab::load(&vp)?
                } else {
                    Vocab::default()
                }
            }
        };
        let corpus_name = synth
            .as_ref()
            .map(|s| s.spec.name.to_string())
            .unwrap_or_else(|| input_path.display().to_string());
        crate::info!("pipeline start: corpus={corpus_name} engine={}", self.config.engine);

        // --- pass 1: variances (with optional checkpoint reuse) -------------
        let cache = if self.config.cache_dir.is_empty() {
            None
        } else {
            // Fingerprint the corpus identity: synthetic params, or the
            // input path + its size (cheap mtime-free invalidation).
            let identity = match &synth {
                Some(s) => format!(
                    "synth:{}:{}:{}:{}",
                    s.spec.name, s.spec.num_docs, s.spec.vocab_size, s.seed
                ),
                None => {
                    let len = std::fs::metadata(&input_path).map(|m| m.len()).unwrap_or(0);
                    format!("file:{}:{len}", input_path.display())
                }
            };
            let key = crate::checkpoint::corpus_key(&identity);
            Some((crate::checkpoint::path_for(Path::new(&self.config.cache_dir), key), key))
        };
        // The corpus' live feature dimension, for checkpoint validation:
        // a cached file whose key collides but whose n differs must be
        // rejected up front, not panic later inside elimination.
        let expected_n: Option<usize> = match &synth {
            Some(s) => Some(s.spec.vocab_size),
            None => crate::data::docword::DocwordReader::open(&input_path)
                .ok()
                .map(|r| r.header().vocab_size),
        };
        let cached_fv = match &cache {
            Some((path, key)) => match crate::checkpoint::load(path, *key, expected_n) {
                Ok(hit) => {
                    if hit.is_some() {
                        crate::info!("variance pass: checkpoint hit at {}", path.display());
                    }
                    hit
                }
                Err(e) => {
                    crate::warn_!("ignoring bad variance checkpoint: {e}");
                    None
                }
            },
            None => None,
        };
        let (fv, stats1) = match cached_fv {
            Some(fv) => {
                let stats = crate::stream::StreamStats {
                    docs: fv.docs,
                    ..Default::default()
                };
                (fv, stats)
            }
            None => {
                let (fv, stats) = prof.time("variance_pass", || -> Result<_, String> {
                    match &synth {
                        Some(s) => variance_pass(&mut SynthSource::new(s), opts),
                        None => {
                            let mut src = FileSource::open(&input_path)?;
                            variance_pass(&mut src, opts)
                        }
                    }
                })?;
                if let Some((path, key)) = &cache {
                    if let Err(e) = crate::checkpoint::save(path, *key, &fv) {
                        crate::warn_!("could not write variance checkpoint: {e}");
                    }
                }
                (fv, stats)
            }
        };
        crate::info!(
            "variance pass: {} docs, {} nnz in {:.2}s",
            stats1.docs,
            stats1.nnz,
            stats1.seconds
        );

        // --- safe elimination ----------------------------------------------
        let (elim, elim_capped) = prof.time("elimination", || {
            choose_elimination(&fv, self.config.target_card, self.config.max_reduced)
        });
        crate::info!(
            "safe elimination: λ={:.4e} keeps n̂={} of n={} ({}x reduction{})",
            elim.lambda,
            elim.reduced(),
            elim.original,
            elim.reduction_factor() as u64,
            if elim_capped { ", capped" } else { "" }
        );
        if elim.reduced() == 0 {
            return Err("elimination removed every feature; lower solver.target λ̂".into());
        }

        // --- pass 2: reduced covariance operator ----------------------------
        let cov: Box<dyn CovOp> = match self.config.cov_backend.as_str() {
            "gram" => {
                let (gram, _stats2) = prof.time("gram_pass", || match &synth {
                    Some(s) => {
                        gram_pass(&mut SynthSource::new(s), &elim, opts, self.config.row_cache_mb)
                    }
                    None => {
                        let mut src = FileSource::open(&input_path)?;
                        gram_pass(&mut src, &elim, opts, self.config.row_cache_mb)
                    }
                })?;
                crate::info!(
                    "gram pass: reduced term matrix nnz={} (row cache {} rows ≤ {} MiB)",
                    gram.nnz(),
                    gram.cache_capacity_rows(),
                    self.config.row_cache_mb
                );
                Box::new(gram)
            }
            _ => {
                let (cov, _stats2) = prof.time("covariance_pass", || match &synth {
                    Some(s) => covariance_pass(&mut SynthSource::new(s), &elim, opts),
                    None => {
                        let mut src = FileSource::open(&input_path)?;
                        covariance_pass(&mut src, &elim, opts)
                    }
                })?;
                Box::new(DenseCov::new(cov))
            }
        };

        // --- solve: λ-search + BCA + rank-K deflation ------------------------
        let mut engine = self.make_engine()?;
        let scheme = Scheme::parse(&self.config.deflation).ok_or("bad deflation scheme")?;
        let mut defl = DeflatedCov::new(cov.as_ref());
        let mut components = Vec::new();
        for k in 0..self.config.num_pcs {
            let t = Timer::start();
            let bca = BcaOptions {
                max_sweeps: self.config.bca_sweeps,
                epsilon: self.config.epsilon,
                tol: 1e-7,
                // The pipeline never reads the per-sweep history, and on
                // the gram backend each history point costs a full pass
                // of Σ-row gathers (frob_with) per sweep.
                track_history: false,
                ..Default::default()
            };
            // Parallel λ-search. The probe schedule comes from config —
            // never derived from the thread count — so the pipeline's
            // numerical results are identical on every machine and for
            // every `threads` setting; threads only change wall time.
            // The default (1) is classic bisection, the best per-eval
            // bracketing for serial runs.
            let sopts = LambdaSearchOptions {
                target_card: self.config.target_card,
                slack: self.config.card_slack,
                bca,
                probes_per_round: self.config.lambda_probes,
                threads: self.config.threads,
                ..Default::default()
            };
            let res = prof.time("lambda_search+bca", || {
                search_with_engine(&mut *engine, &defl, &sopts)
            })?;
            let words: Vec<String> = res
                .pc
                .support
                .iter()
                .map(|&r| vocab.word(elim.kept[r]))
                .collect();
            crate::info!(
                "PC {}: card={} λ={:.4} φ={:.4} [{}] in {:.2}s",
                k + 1,
                res.pc.cardinality(),
                res.lambda,
                res.solution.phi,
                words.join(", "),
                t.secs()
            );
            let explained = defl.quad_form(&res.pc.vector);
            let certificate_gap = if self.config.certify {
                let cert = prof.time("certificate", || {
                    // certify on the survivors of res.lambda (the solve
                    // space); the eliminated coordinates are provably zero.
                    // The certificate's eigendecompositions need an
                    // explicit matrix, so the survivor submatrix is
                    // materialized here (small: the solve space).
                    let diags: Vec<f64> = (0..defl.n()).map(|i| defl.diag(i)).collect();
                    let sub_elim = crate::elim::SafeElimination::apply(&diags, res.lambda, None);
                    let sub = defl.materialize(&sub_elim.kept);
                    crate::solver::certificate::certify(&sub, &res.solution.z, res.lambda)
                });
                crate::info!(
                    "PC {} certificate: φ={:.4} ≤ {:.4} (gap {:.2e})",
                    k + 1,
                    cert.primal,
                    cert.upper_bound,
                    cert.gap
                );
                Some(cert.gap)
            } else {
                None
            };
            prof.time("deflation", || defl.push(scheme, &res.pc.vector));
            components.push(ComponentReport {
                lambda: res.lambda,
                phi: res.solution.phi,
                explained_variance: explained,
                words,
                seconds: t.secs(),
                pc: res.pc,
                certificate_gap,
            });
        }

        let topic_table = crate::report::topic_table(
            &components.iter().map(|c| c.pc.clone()).collect::<Vec<_>>(),
            &vocab,
            Some(&elim.kept),
        );

        // --- model artifact: the hand-off to `score` / `serve` ---------------
        let n_orig = fv.variance.len();
        let model = crate::model::Model {
            corpus_name: corpus_name.clone(),
            num_docs: stats1.docs,
            n_features: n_orig,
            vocab_hash: crate::model::vocab_hash(&vocab),
            seed: self.config.seed,
            elim_lambda: elim.lambda,
            kept: elim.kept.clone(),
            kept_means: elim.kept.iter().map(|&i| fv.mean[i]).collect(),
            kept_stds: elim.kept.iter().map(|&i| fv.variance[i].sqrt()).collect(),
            kept_words: elim.kept.iter().map(|&i| vocab.word(i)).collect(),
            pcs: components
                .iter()
                .map(|c| crate::model::ModelPc {
                    lambda: c.lambda,
                    phi: c.phi,
                    explained_variance: c.explained_variance,
                    loadings: c.pc.mapped(&elim.kept, n_orig).loadings(),
                })
                .collect(),
        };
        if !self.config.save_model.is_empty() {
            model.save(Path::new(&self.config.save_model))?;
            crate::info!("model artifact written to {}", self.config.save_model);
        }

        Ok(PipelineReport {
            corpus_name,
            num_docs: stats1.docs as usize,
            vocab_size: fv.variance.len(),
            nnz: stats1.nnz,
            sorted_variances: fv.sorted_variances(),
            reduced_size: elim.reduced(),
            reduction_factor: elim.reduction_factor(),
            elim_lambda: elim.lambda,
            elim_capped,
            components,
            profile: prof.report(),
            total_seconds: total.secs(),
            topic_table,
            model,
        })
    }
}

/// Choose the elimination λ̂ for a target PC cardinality: keep a working
/// set comfortably larger than the target (the λ-search then operates
/// inside it), capped at `max_reduced`. Returns the elimination and
/// whether the cap bound.
pub fn choose_elimination(
    fv: &FeatureVariances,
    target_card: usize,
    max_reduced: usize,
) -> (SafeElimination, bool) {
    // Working set ~ 40× the target cardinality mirrors the paper's
    // observation (target 5 → n̂ ≤ ~500 on NYTimes within a ~100k vocab).
    let want = (target_card * 40).min(max_reduced).max(target_card);
    let lam = lambda_for_survivors(&fv.variance, want);
    let elim = SafeElimination::from_variances(fv, lam, Some(max_reduced));
    let capped = elim.capped(&fv.variance);
    (elim, capped)
}

/// λ-search where the inner solves run on an [`Engine`].
pub fn search_with_engine(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    opts: &LambdaSearchOptions,
) -> Result<crate::solver::lambda::LambdaSearchResult, String> {
    match engine.name() {
        // The native fast path uses the allocation-free direct solver.
        "native" => Ok(search(sigma, opts)),
        _ => {
            // Engine-generic path: replicate the search but solve via engine.
            let mut eopts = *opts;
            eopts.bca.track_history = false;
            engine_search(engine, sigma, &eopts)
        }
    }
}

fn engine_search(
    engine: &mut dyn Engine,
    sigma: &dyn CovOp,
    opts: &LambdaSearchOptions,
) -> Result<crate::solver::lambda::LambdaSearchResult, String> {
    use crate::solver::extract::leading_sparse_pc;
    use crate::solver::lambda::{LambdaEval, LambdaSearchResult};
    let n = sigma.n();
    let max_diag = (0..n).map(|i| sigma.diag(i)).fold(0.0f64, f64::max);
    let (mut lo, mut hi) = (0.0f64, max_diag * 0.999);
    let mut lambda = 0.5 * hi;
    let mut trace = Vec::new();
    let mut best: Option<(f64, crate::solver::bca::BcaSolution, SparsePc)> = None;
    let mut best_key = (usize::MAX, f64::NEG_INFINITY);
    let diags: Vec<f64> = (0..n).map(|i| sigma.diag(i)).collect();
    for evals in 0..opts.max_evals {
        // Per-probe safe elimination (Thm 2.1), mirroring the native
        // search: solve on the masked survivor view and lift back.
        let elim = crate::elim::SafeElimination::apply(&diags, lambda, None);
        let use_mask =
            opts.per_lambda_elim && elim.reduced() != n && elim.reduced() != 0;
        let (sol, pc) = if !use_mask {
            let sol = crate::engine::bca_solve(engine, sigma, lambda, &opts.bca)?;
            let pc = leading_sparse_pc(&sol.z, opts.extract_tol);
            (sol, pc)
        } else {
            let sub = MaskedCov::new(sigma, elim.kept.clone());
            let sol = crate::engine::bca_solve(engine, &sub, lambda, &opts.bca)?;
            let pc = leading_sparse_pc(&sol.z, opts.extract_tol).mapped(&elim.kept, n);
            (sol, pc)
        };
        let card = pc.cardinality();
        trace.push(LambdaEval { lambda, cardinality: card, phi: sol.phi });
        let key = (card.abs_diff(opts.target_card), sol.phi);
        if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 > best_key.1) {
            best_key = key;
            best = Some((lambda, sol, pc));
        }
        let dist = card.abs_diff(opts.target_card);
        if dist == 0 || (dist <= opts.slack && evals + 1 >= opts.max_evals / 2) {
            break;
        }
        if card > opts.target_card {
            lo = lambda;
        } else {
            hi = lambda;
        }
        lambda = 0.5 * (lo + hi);
        if (hi - lo) < 1e-12 * (1.0 + max_diag) {
            break;
        }
    }
    let (lambda, solution, pc) = best.ok_or("no evaluations")?;
    let hit_target = pc.cardinality().abs_diff(opts.target_card) <= opts.slack;
    Ok(LambdaSearchResult { lambda, solution, pc, trace, hit_target })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> PipelineConfig {
        PipelineConfig {
            synth_preset: "nytimes".into(),
            synth_docs: 800,
            synth_vocab: 3000,
            workers: 2,
            chunk_docs: 128,
            num_pcs: 3,
            target_card: 5,
            card_slack: 2,
            max_reduced: 64,
            bca_sweeps: 6,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_tiny_nytimes() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        assert_eq!(report.num_docs, 800);
        assert!(report.reduced_size > 0 && report.reduced_size <= 64);
        assert!(report.reduction_factor > 10.0, "reduction {}", report.reduction_factor);
        assert_eq!(report.components.len(), 3);
        for c in &report.components {
            assert!(c.pc.cardinality() >= 1);
            assert!(c.pc.cardinality() <= 5 + 4, "card {}", c.pc.cardinality());
            assert!(!c.words.is_empty());
        }
        // topic table mentions at least one planted word from Table 1
        let planted = ["million", "percent", "point", "play", "official", "president", "school"];
        assert!(
            planted.iter().any(|w| report.topic_table.contains(w)),
            "topic table:\n{}",
            report.topic_table
        );
        // Fig 2 series is sorted descending
        assert!(report
            .sorted_variances
            .windows(2)
            .all(|w| w[0] >= w[1]));
    }

    #[test]
    fn first_pc_recovers_a_planted_topic() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        // The strongest PC should consist mostly of words from ONE topic.
        let spec = CorpusSpec::nytimes();
        let first = &report.components[0];
        let mut best_overlap = 0usize;
        for t in &spec.topics {
            let overlap = first
                .words
                .iter()
                .filter(|w| t.words.contains(&w.as_str()))
                .count();
            best_overlap = best_overlap.max(overlap);
        }
        assert!(
            best_overlap * 2 >= first.words.len(),
            "PC1 words {:?} do not concentrate on one topic",
            first.words
        );
    }

    #[test]
    fn report_model_is_consistent_with_components() {
        let report = Pipeline::new(tiny_config()).run().unwrap();
        let m = &report.model;
        m.validate().unwrap();
        assert_eq!(m.n_features, report.vocab_size);
        assert_eq!(m.kept.len(), report.reduced_size);
        assert_eq!(m.pcs.len(), report.components.len());
        assert_eq!(m.num_docs as usize, report.num_docs);
        for (c, pc) in report.components.iter().zip(&m.pcs) {
            assert_eq!(pc.loadings.len(), c.pc.cardinality());
            // original-space loadings are the reduced PC pushed through
            // the kept map, bit for bit, in the same support order
            for (&(orig, w), &r) in pc.loadings.iter().zip(&c.pc.support) {
                assert_eq!(orig, m.kept[r]);
                assert_eq!(w.to_bits(), c.pc.vector[r].to_bits());
            }
            assert_eq!(pc.lambda, c.lambda);
        }
        // the model's top word per PC matches the reported word list
        for (c, pc) in report.components.iter().zip(&m.pcs) {
            assert_eq!(m.word_of(pc.loadings[0].0), c.words[0]);
        }
    }

    #[test]
    fn choose_elimination_respects_cap() {
        let fv = crate::moments::FeatureVariances {
            variance: (0..1000).map(|i| 1.0 / (1.0 + i as f64)).collect(),
            mean: vec![0.0; 1000],
            second_moment: vec![0.0; 1000],
            docs: 10,
        };
        let (elim, capped) = choose_elimination(&fv, 5, 50);
        assert!(elim.reduced() <= 50);
        assert!(!capped || elim.reduced() == 50);
    }
}
