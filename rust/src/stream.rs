//! Streaming orchestration: bounded channels with backpressure and a
//! worker pool that folds document chunks into mergeable accumulators.
//!
//! This is the coordination layer for the paper's pre-processing passes.
//! The corpora are larger than memory, so a single reader thread streams
//! chunks into a *bounded* queue (backpressure: the reader blocks when the
//! workers fall behind), and `W` workers fold chunks into thread-local
//! accumulators that merge associatively at the end. The paper notes this
//! pass "is easy to parallelize"; this module is that claim, made concrete.
//!
//! (The scaffold suggested tokio; it is not available in the offline
//! vendor set, so this uses `std::thread` + a hand-rolled bounded channel —
//! same semantics, see DESIGN.md §3.)

use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::data::docword::{DocChunk, DocwordHeader, DocwordReader};
use crate::error::LsspcaError;
use crate::moments::{FeatureMoments, FeatureVariances};

// ---------------------------------------------------------------------------
// Bounded MPMC channel
// ---------------------------------------------------------------------------

struct ChannelInner<T> {
    queue: Mutex<ChannelState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    /// Live receiver handles. When it drops to zero the senders unblock
    /// and start failing — this is what turns "all workers died" into an
    /// error instead of a deadlocked reader (see worker_panic test).
    receivers: AtomicUsize,
}

struct ChannelState<T> {
    buf: VecDeque<T>,
    cap: usize,
    closed: bool,
}

/// Sending half of a bounded channel.
pub struct BoundedSender<T> {
    inner: Arc<ChannelInner<T>>,
}

/// Why a [`BoundedSender::try_send`] did not enqueue; the item is handed
/// back so the caller can respond to its owner (e.g. write a 503).
#[derive(Debug)]
pub enum TrySendError<T> {
    /// Queue at capacity right now.
    Full(T),
    /// Channel closed or all receivers gone.
    Closed(T),
}

/// Why a [`BoundedReceiver::try_recv`] / [`BoundedReceiver::recv_timeout`]
/// returned no item.
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    /// Queue empty right now (or the timeout elapsed).
    Empty,
    /// Channel closed and fully drained — no item will ever arrive.
    Closed,
}

/// Receiving half of a bounded channel (cloneable: multiple workers).
pub struct BoundedReceiver<T> {
    inner: Arc<ChannelInner<T>>,
}

impl<T> Clone for BoundedReceiver<T> {
    fn clone(&self) -> Self {
        self.inner.receivers.fetch_add(1, Ordering::SeqCst);
        BoundedReceiver { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Drop for BoundedReceiver<T> {
    fn drop(&mut self) {
        if self.inner.receivers.fetch_sub(1, Ordering::SeqCst) == 1 {
            // last receiver gone: wake blocked senders so they can error out
            self.inner.not_full.notify_all();
        }
    }
}

/// Create a bounded channel with capacity `cap`.
pub fn bounded<T>(cap: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    assert!(cap > 0);
    let inner = Arc::new(ChannelInner {
        queue: Mutex::new(ChannelState { buf: VecDeque::with_capacity(cap), cap, closed: false }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        receivers: AtomicUsize::new(1),
    });
    (BoundedSender { inner: Arc::clone(&inner) }, BoundedReceiver { inner })
}

impl<T> BoundedSender<T> {
    /// Blocking send; returns `Err(item)` if the channel was closed or
    /// every receiver is gone (e.g. all workers panicked).
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed || self.inner.receivers.load(Ordering::SeqCst) == 0 {
                return Err(item);
            }
            if st.buf.len() < st.cap {
                st.buf.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; never waits for queue space. The server's accept
    /// loop uses this to shed load (503 + `Retry-After`) instead of letting
    /// a full worker pool back up into the listener.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed || self.inner.receivers.load(Ordering::SeqCst) == 0 {
            return Err(TrySendError::Closed(item));
        }
        if st.buf.len() < st.cap {
            st.buf.push_back(item);
            self.inner.not_empty.notify_one();
            return Ok(());
        }
        Err(TrySendError::Full(item))
    }

    /// Close the channel; receivers drain the remaining items then see EOF.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }
}

impl<T> Drop for BoundedSender<T> {
    fn drop(&mut self) {
        self.close();
    }
}

impl<T> BoundedReceiver<T> {
    /// Blocking receive; `None` = channel closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive. The serving event loop drains its accept
    /// queue with this between connection ticks, so a worker with live
    /// connections never parks on the channel.
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut st = self.inner.queue.lock().unwrap();
        match st.buf.pop_front() {
            Some(item) => {
                self.inner.not_full.notify_one();
                Ok(item)
            }
            None if st.closed => Err(TryRecvError::Closed),
            None => Err(TryRecvError::Empty),
        }
    }

    /// Blocking receive with a deadline: waits at most `timeout` for an
    /// item. [`TryRecvError::Empty`] means the timeout elapsed; the
    /// channel may still produce items later.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Result<T, TryRecvError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Ok(item);
            }
            if st.closed {
                return Err(TryRecvError::Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(TryRecvError::Empty);
            }
            let (guard, _) = self.inner.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

// ---------------------------------------------------------------------------
// Chunk sources
// ---------------------------------------------------------------------------

/// Anything that can produce document chunks in order.
pub trait ChunkSource {
    /// Total features (vocabulary size).
    fn num_features(&self) -> usize;
    /// Next chunk of at most `max_docs` documents, `None` at end.
    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError>;
}

/// Stream from a docword file.
pub struct FileSource {
    reader: DocwordReader,
}

impl FileSource {
    /// Open a docword file (`.gz` transparently).
    pub fn open(path: &Path) -> Result<FileSource, LsspcaError> {
        Ok(FileSource { reader: DocwordReader::open(path)? })
    }

    /// Open with an optional dead-letter [`crate::deadletter::RecordPolicy`]:
    /// malformed records are quarantined and skipped (within the policy's
    /// budget) instead of aborting the pass.
    pub fn open_with_policy(
        path: &Path,
        policy: Option<crate::deadletter::RecordPolicy>,
    ) -> Result<FileSource, LsspcaError> {
        Ok(FileSource { reader: DocwordReader::open_with_policy(path, policy)? })
    }

    /// The file's declared `(D, W, NNZ)` header.
    pub fn header(&self) -> DocwordHeader {
        self.reader.header()
    }

    /// Distinct records quarantined so far (0 when strict).
    pub fn bad_records(&self) -> u64 {
        self.reader.bad_records()
    }
}

impl ChunkSource for FileSource {
    fn num_features(&self) -> usize {
        self.reader.header().vocab_size
    }

    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        self.reader.next_chunk(max_docs)
    }
}

/// Stream documents straight out of a synthetic corpus generator, without
/// materializing a file (used by tests and in-memory benchmarks).
pub struct SynthSource<'a> {
    corpus: &'a crate::corpus::SynthCorpus,
    next_doc: usize,
}

impl<'a> SynthSource<'a> {
    /// Stream from document 0 of `corpus`.
    pub fn new(corpus: &'a crate::corpus::SynthCorpus) -> SynthSource<'a> {
        SynthSource { corpus, next_doc: 0 }
    }

    /// Stream from document ordinal `doc` (clamped to the corpus size).
    /// The generator is position-seeded per document, so starting
    /// mid-corpus yields exactly the documents a from-zero stream would
    /// have produced at those ordinals — the property the distributed
    /// shard workers rely on to skip straight to their shard.
    pub fn starting_at(corpus: &'a crate::corpus::SynthCorpus, doc: u64) -> SynthSource<'a> {
        SynthSource { corpus, next_doc: (doc as usize).min(corpus.spec.num_docs) }
    }
}

impl ChunkSource for SynthSource<'_> {
    fn num_features(&self) -> usize {
        self.corpus.spec.vocab_size
    }

    fn next_chunk(&mut self, max_docs: usize) -> Result<Option<DocChunk>, LsspcaError> {
        let total = self.corpus.spec.num_docs;
        if self.next_doc >= total {
            return Ok(None);
        }
        let end = (self.next_doc + max_docs).min(total);
        let docs = (self.next_doc..end)
            .map(|d| crate::data::docword::Doc { id: d, words: self.corpus.generate_doc(d) })
            .collect();
        self.next_doc = end;
        Ok(Some(DocChunk { docs }))
    }
}

// ---------------------------------------------------------------------------
// Parallel fold
// ---------------------------------------------------------------------------

/// Options for a streaming pass.
#[derive(Clone, Copy, Debug)]
pub struct StreamOptions {
    /// Worker threads folding chunks.
    pub workers: usize,
    /// Documents per streamed chunk (fixed → deterministic shards).
    pub chunk_docs: usize,
    /// Bounded queue depth between reader and workers (backpressure).
    pub queue_depth: usize,
}

impl Default for StreamOptions {
    fn default() -> Self {
        StreamOptions { workers: 2, chunk_docs: 2048, queue_depth: 4 }
    }
}

/// Statistics from a completed pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Documents streamed.
    pub docs: u64,
    /// `(word, count)` pairs streamed.
    pub nnz: u64,
    /// Chunks handed to workers.
    pub chunks: u64,
    /// Wall time of the pass.
    pub seconds: f64,
}

/// Fold every chunk of `source` through worker-local accumulators.
///
/// `make_acc` builds one accumulator per worker, `fold` consumes a chunk,
/// `merge` combines two accumulators. The reader applies backpressure via
/// the bounded queue. Worker panics are converted to errors.
pub fn parallel_fold<S, A, FM, FF, FG>(
    source: &mut S,
    opts: StreamOptions,
    make_acc: FM,
    fold: FF,
    merge: FG,
) -> Result<(A, StreamStats), LsspcaError>
where
    S: ChunkSource,
    A: Send + 'static,
    FM: Fn() -> A,
    FF: Fn(&mut A, &DocChunk) + Send + Sync + 'static,
    FG: Fn(&mut A, A),
{
    assert!(opts.workers >= 1 && opts.chunk_docs >= 1 && opts.queue_depth >= 1);
    let t0 = std::time::Instant::now();
    let (tx, rx) = bounded::<DocChunk>(opts.queue_depth);
    let fold = Arc::new(fold);
    let mut stats = StreamStats::default();

    let result: Result<A, LsspcaError> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..opts.workers {
            let rx = rx.clone();
            let fold = Arc::clone(&fold);
            let mut acc = make_acc();
            handles.push(scope.spawn(move || {
                while let Some(chunk) = rx.recv() {
                    fold(&mut acc, &chunk);
                }
                acc
            }));
        }
        drop(rx);

        // Reader loop (this thread): stream chunks into the bounded queue.
        let mut read_err = None;
        loop {
            match source.next_chunk(opts.chunk_docs) {
                Err(e) => {
                    read_err = Some(e);
                    break;
                }
                Ok(None) => break,
                Ok(Some(chunk)) => {
                    stats.docs += chunk.docs.len() as u64;
                    stats.nnz += chunk.total_nnz() as u64;
                    stats.chunks += 1;
                    if tx.send(chunk).is_err() {
                        read_err = Some(LsspcaError::corpus("all workers exited early"));
                        break;
                    }
                }
            }
        }
        tx.close();

        let mut final_acc: Option<A> = None;
        let mut panic_err = None;
        for h in handles {
            match h.join() {
                Ok(acc) => match final_acc {
                    None => final_acc = Some(acc),
                    Some(ref mut f) => merge(f, acc),
                },
                Err(_) => panic_err = Some(LsspcaError::corpus("worker thread panicked")),
            }
        }
        if let Some(e) = read_err {
            return Err(e);
        }
        if let Some(e) = panic_err {
            return Err(e);
        }
        final_acc.ok_or_else(|| LsspcaError::corpus("no workers"))
    });

    stats.seconds = t0.elapsed().as_secs_f64();
    result.map(|acc| (acc, stats))
}

/// The paper's pre-processing pass: streamed per-feature variances.
pub fn variance_pass<S: ChunkSource>(
    source: &mut S,
    opts: StreamOptions,
) -> Result<(FeatureVariances, StreamStats), LsspcaError> {
    let nf = source.num_features();
    let (acc, stats) = parallel_fold(
        source,
        opts,
        || FeatureMoments::new(nf),
        |acc: &mut FeatureMoments, chunk| acc.push_chunk(chunk),
        |a, b| a.merge(&b),
    )?;
    Ok((acc.finalize_par(opts.workers), stats))
}

/// A deterministic, kill-resumable variance pass.
///
/// [`variance_pass`] merges worker-local accumulators in thread-completion
/// order — fine under an f64 *tolerance*, but not stable enough for the
/// fault-tolerance contract, which demands that a run killed mid-pass and
/// resumed from a [`crate::jobstate`] file produce **bitwise-identical**
/// variances. This variant restores determinism by construction:
///
/// - each chunk is folded into a **fresh** per-chunk accumulator on
///   whatever worker picks it up (per-chunk arithmetic is sequential and
///   thread-independent);
/// - a dedicated merger thread merges per-chunk results into the master
///   accumulator in **strict chunk-index order**, parking out-of-order
///   arrivals in a `BTreeMap` until their turn;
/// - because [`crate::util::stats::RunningStats::merge`] into an empty
///   accumulator is an exact copy, the master after chunks `0..k` is the
///   same f64 sequence regardless of worker count — and a master
///   *deserialized* from a job state saved at chunk `k` is bitwise equal
///   to one that folded `0..k` in-process (the format stores exact
///   `f64::to_le_bytes`).
///
/// `resume` restores `(partial accumulator, completed_chunks)` from a job
/// state; the reader re-reads and discards the completed prefix (gzip
/// streams cannot seek) so document/nnz totals still match an
/// uninterrupted run. `persist` is invoked with the master and the number
/// of completed chunks every `persist_every` merged chunks (0 = never);
/// a persist failure aborts the pass — by the time it is called the
/// retry budget has already been spent inside [`crate::jobstate::save`].
pub fn resumable_variance_pass<S, F>(
    source: &mut S,
    opts: StreamOptions,
    resume: Option<(FeatureMoments, u64)>,
    persist_every: u64,
    persist: F,
) -> Result<(FeatureVariances, StreamStats), LsspcaError>
where
    S: ChunkSource,
    F: FnMut(&FeatureMoments, u64) -> Result<(), LsspcaError> + Send,
{
    assert!(opts.workers >= 1 && opts.chunk_docs >= 1 && opts.queue_depth >= 1);
    let t0 = std::time::Instant::now();
    let nf = source.num_features();
    let (start_state, skip_chunks) = match resume {
        Some((m, done)) => {
            assert_eq!(m.num_features(), nf, "resume state feature count mismatch");
            (m, done)
        }
        None => (FeatureMoments::new(nf), 0),
    };
    let (work_tx, work_rx) = bounded::<(u64, DocChunk)>(opts.queue_depth);
    let (res_tx, res_rx) = bounded::<(u64, FeatureMoments)>(opts.queue_depth.max(opts.workers));
    let mut stats = StreamStats::default();

    let result: Result<FeatureMoments, LsspcaError> = std::thread::scope(|scope| {
        let res_tx = &res_tx;
        let mut workers = Vec::new();
        for _ in 0..opts.workers {
            let rx = work_rx.clone();
            workers.push(scope.spawn(move || {
                while let Some((idx, chunk)) = rx.recv() {
                    let mut acc = FeatureMoments::new(nf);
                    acc.push_chunk(&chunk);
                    if res_tx.send((idx, acc)).is_err() {
                        break;
                    }
                }
            }));
        }
        drop(work_rx);

        let merger = scope.spawn({
            let mut persist = persist;
            let mut master = start_state;
            move || -> Result<FeatureMoments, LsspcaError> {
                let mut pending: std::collections::BTreeMap<u64, FeatureMoments> =
                    std::collections::BTreeMap::new();
                let mut next = skip_chunks;
                let mut unsaved = 0u64;
                while let Some((idx, acc)) = res_rx.recv() {
                    pending.insert(idx, acc);
                    while let Some(acc) = pending.remove(&next) {
                        master.merge(&acc);
                        next += 1;
                        unsaved += 1;
                        if persist_every > 0 && unsaved >= persist_every {
                            persist(&master, next)?;
                            unsaved = 0;
                        }
                    }
                }
                // Leftover `pending` entries mean a worker died mid-chunk;
                // the reader/worker error paths below report the cause.
                Ok(master)
            }
        });

        // Reader loop (this thread).
        let mut read_err = None;
        let mut idx = 0u64;
        loop {
            match source.next_chunk(opts.chunk_docs) {
                Err(e) => {
                    read_err = Some(e);
                    break;
                }
                Ok(None) => break,
                Ok(Some(chunk)) => {
                    stats.docs += chunk.docs.len() as u64;
                    stats.nnz += chunk.total_nnz() as u64;
                    stats.chunks += 1;
                    let i = idx;
                    idx += 1;
                    if i < skip_chunks {
                        continue; // already folded into the restored state
                    }
                    if work_tx.send((i, chunk)).is_err() {
                        read_err = Some(LsspcaError::corpus("all workers exited early"));
                        break;
                    }
                }
            }
        }
        work_tx.close();

        let mut panic_err = None;
        for h in workers {
            if h.join().is_err() {
                panic_err = Some(LsspcaError::corpus("worker thread panicked"));
            }
        }
        res_tx.close();
        // A merger error (persist failure) is the root cause: it makes the
        // workers and reader shut down with symptom errors, so report it
        // first rather than "all workers exited early".
        let acc = match merger.join() {
            Ok(r) => r?,
            Err(_) => return Err(LsspcaError::corpus("merger thread panicked")),
        };
        if let Some(e) = read_err {
            return Err(e);
        }
        if let Some(e) = panic_err {
            return Err(e);
        }
        Ok(acc)
    });

    stats.seconds = t0.elapsed().as_secs_f64();
    result.map(|acc| (acc.finalize_par(opts.workers), stats))
}

/// Convenience: variance pass over a docword file.
pub fn variance_pass_file(
    path: &Path,
    opts: StreamOptions,
) -> Result<(DocwordHeader, FeatureVariances, StreamStats), LsspcaError> {
    let mut src = FileSource::open(path)?;
    let header = src.header();
    let (fv, stats) = variance_pass(&mut src, opts)?;
    Ok((header, fv, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusSpec, SynthCorpus};
    use crate::util::check::close_slice;

    #[test]
    fn bounded_channel_fifo_and_close() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        assert!(tx.send(3).is_err());
    }

    #[test]
    fn try_send_reports_full_and_closed() {
        let (tx, rx) = bounded::<u32>(1);
        assert!(tx.try_send(1).is_ok());
        match tx.try_send(2) {
            Err(TrySendError::Full(2)) => {}
            other => panic!("want Full(2), got {other:?}"),
        }
        assert_eq!(rx.recv(), Some(1));
        assert!(tx.try_send(3).is_ok());
        tx.close();
        match tx.try_send(4) {
            Err(TrySendError::Closed(4)) => {}
            other => panic!("want Closed(4), got {other:?}"),
        }
    }

    #[test]
    fn try_recv_reports_empty_and_closed() {
        let (tx, rx) = bounded::<u32>(2);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        tx.send(7).unwrap();
        assert_eq!(rx.try_recv(), Ok(7));
        tx.close();
        assert_eq!(rx.try_recv(), Err(TryRecvError::Closed));
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        use std::time::Duration;
        let (tx, rx) = bounded::<u32>(1);
        let t0 = std::time::Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(20)), Err(TryRecvError::Empty));
        assert!(t0.elapsed() >= Duration::from_millis(15));
        tx.send(9).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(100)), Ok(9));
        drop(tx); // sender drop closes
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Err(TryRecvError::Closed));
    }

    #[test]
    fn send_fails_when_receivers_gone() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap(); // fills the queue
        drop(rx);
        // would deadlock before the receiver-count fix; must error instead
        assert!(tx.send(2).is_err());
    }

    #[test]
    fn bounded_channel_blocks_and_resumes() {
        let (tx, rx) = bounded::<u32>(1);
        let h = std::thread::spawn(move || {
            // second send must block until the consumer drains
            tx.send(1).unwrap();
            tx.send(2).unwrap();
            "sent"
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(h.join().unwrap(), "sent");
    }

    fn corpus() -> SynthCorpus {
        SynthCorpus::new(CorpusSpec::nytimes().scaled(300, 1200), 17)
    }

    #[test]
    fn parallel_variance_equals_serial() {
        let c = corpus();
        // serial reference
        let mut serial = crate::moments::FeatureMoments::new(c.spec.vocab_size);
        for d in 0..c.spec.num_docs {
            serial.push_doc(&c.generate_doc(d));
        }
        let want = serial.finalize();
        for workers in [1, 2, 4] {
            let mut src = SynthSource::new(&c);
            let opts = StreamOptions { workers, chunk_docs: 37, queue_depth: 3 };
            let (got, stats) = variance_pass(&mut src, opts).unwrap();
            assert_eq!(stats.docs, 300);
            close_slice(&got.variance, &want.variance, 1e-10).unwrap();
            close_slice(&got.mean, &want.mean, 1e-10).unwrap();
        }
    }

    #[test]
    fn file_pass_matches_synth_pass() {
        let c = corpus();
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_stream_{}.txt.gz", std::process::id()));
        c.write_docword(&p).unwrap();
        let opts = StreamOptions { workers: 2, chunk_docs: 50, queue_depth: 2 };
        let (hdr, from_file, _) = variance_pass_file(&p, opts).unwrap();
        assert_eq!(hdr.num_docs, 300);
        let mut src = SynthSource::new(&c);
        let (from_mem, _) = variance_pass(&mut src, opts).unwrap();
        close_slice(&from_file.variance, &from_mem.variance, 1e-12).unwrap();
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(p.with_extension("vocab")).ok();
    }

    #[test]
    fn resumable_pass_is_bitwise_stable_across_resume_points() {
        let c = corpus();
        let opts = StreamOptions { workers: 3, chunk_docs: 37, queue_depth: 2 };
        // Uninterrupted run, capturing the master state after every chunk.
        let states = std::sync::Mutex::new(Vec::<(u64, FeatureMoments)>::new());
        let mut src = SynthSource::new(&c);
        let (want, stats) = resumable_variance_pass(&mut src, opts, None, 1, |m, done| {
            states.lock().unwrap().push((done, m.clone()));
            Ok(())
        })
        .unwrap();
        assert_eq!(stats.docs, 300);
        let states = states.into_inner().unwrap();
        assert_eq!(states.len() as u64, stats.chunks);
        // tolerance-level agreement with the completion-order pass
        let mut src = SynthSource::new(&c);
        let (plain, _) = variance_pass(&mut src, opts).unwrap();
        close_slice(&plain.variance, &want.variance, 1e-10).unwrap();
        // Resume from several interruption points: bitwise identical.
        for &(done, ref state) in [&states[0], &states[states.len() / 2], &states[states.len() - 2]]
        {
            let mut src = SynthSource::new(&c);
            let (got, rstats) =
                resumable_variance_pass(&mut src, opts, Some((state.clone(), done)), 0, |_, _| {
                    Ok(())
                })
                .unwrap();
            assert_eq!(rstats.docs, 300, "resumed stats re-count the whole corpus");
            assert_eq!(got.docs, want.docs);
            for i in 0..got.variance.len() {
                assert_eq!(got.variance[i].to_bits(), want.variance[i].to_bits(), "feature {i}");
                assert_eq!(got.mean[i].to_bits(), want.mean[i].to_bits(), "feature {i}");
                assert_eq!(
                    got.second_moment[i].to_bits(),
                    want.second_moment[i].to_bits(),
                    "feature {i}"
                );
            }
        }
    }

    #[test]
    fn resumable_pass_persist_failure_is_root_cause() {
        let c = corpus();
        let mut src = SynthSource::new(&c);
        let mut calls = 0;
        let err = resumable_variance_pass(
            &mut src,
            StreamOptions { workers: 2, chunk_docs: 16, queue_depth: 2 },
            None,
            2,
            |_, _| {
                calls += 1;
                if calls >= 2 {
                    Err(LsspcaError::cache("job state disk full"))
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("disk full"), "{err}");
    }

    #[test]
    fn worker_panic_reported() {
        let c = corpus();
        let mut src = SynthSource::new(&c);
        let res: Result<(u64, _), LsspcaError> = parallel_fold(
            &mut src,
            StreamOptions { workers: 2, chunk_docs: 64, queue_depth: 2 },
            || 0u64,
            |_, _| panic!("injected failure"),
            |a, b| *a += b,
        );
        let err = res.unwrap_err().to_string();
        assert!(err.contains("panicked") || err.contains("exited early"), "{err}");
    }

    #[test]
    fn read_error_reported() {
        struct Broken;
        impl ChunkSource for Broken {
            fn num_features(&self) -> usize {
                1
            }
            fn next_chunk(&mut self, _: usize) -> Result<Option<DocChunk>, LsspcaError> {
                Err(LsspcaError::corpus("disk on fire"))
            }
        }
        let res = variance_pass(&mut Broken, StreamOptions::default());
        assert!(res.unwrap_err().to_string().contains("disk on fire"));
    }
}
