//! The out-of-core covariance backend (`[cov] backend = "disk"`).
//!
//! [`DiskGramCov`] serves the implicit centered covariance
//! `Σ = AᵀA/m − μμᵀ` of a reduced term matrix that lives **on disk** as a
//! [`crate::data::shardcache`] — column-range CSC shards plus a manifest
//! with the per-feature means and Σ diagonal. Resident memory is a
//! configured budget (the LRU row cache plus one streaming wave of
//! shards), not a function of the corpus, which moves the pipeline's
//! ceiling from "reduced matrix fits in RAM" to "reduced matrix fits on
//! disk".
//!
//! ## Bitwise equality with [`GramCov`]
//!
//! Every kernel here replays the exact floating-point summation order of
//! the in-memory [`GramCov`] over the same doc-id-sorted, column-sorted
//! reduced CSR, so solves through this operator are **bitwise identical**
//! to in-memory ones (pinned by `rust/tests/oocore.rs`):
//!
//! - *matvec, first half* (`ax = A x`): shards are swept in column
//!   order, scattering `ax[d] += v·x[c]` — for each document the terms
//!   arrive in ascending reduced-column order, which is the CSR row's own
//!   (canonical, sorted) order.
//! - *matvec, second half* (`y = Aᵀax`): each shard owns a disjoint
//!   `y[c0..c1)` range; per column the terms run over ascending document
//!   id, the order the in-memory row-major scatter produces. Ranges are
//!   computed on [`crate::util::parallel`] workers and stitched in shard
//!   order.
//! - *row gather* (`Σ_j`): a sorted-merge dot of column `j` against each
//!   column `k` accumulates over exactly the documents containing both
//!   features, in ascending id order — the order [`GramCov`]'s
//!   `compute_row` folds them.
//!
//! The means and diagonal are computed once at cache-write time with the
//! same folds (`shardcache::write`), and gathered rows land in the same
//! `Mutex`-guarded LRU row cache type, resized to the `[memory]` budget.
//! Caching and thread count never change a value, only wall time.
//!
//! ## Failure model
//!
//! [`crate::covop::CovOp`] methods cannot return errors, and a solver
//! mid-BCA has no way to continue without the data, so an I/O or
//! integrity failure while streaming a shard **panics** with the
//! underlying error. Corrupt caches are normally caught before any
//! solve starts: the coordinator verifies the manifest at
//! [`crate::data::shardcache::open`] and every shard via
//! [`crate::data::shardcache::verify_shards`] on a cache hit,
//! rebuilding on failure — the panic is the backstop for bit rot that
//! happens *during* a run.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::covop::{CovOp, GramCov, RowCache};
use crate::data::shardcache::{self, ShardBlock, ShardCacheKey, ShardManifest};
use crate::util::parallel::{par_map_indexed, resolve_threads};

/// Implicit centered covariance streamed from an on-disk shard cache —
/// the `"disk"` covariance backend. See the module docs for the memory
/// and determinism contracts.
pub struct DiskGramCov {
    dir: PathBuf,
    man: ShardManifest,
    /// Worker threads for shard-parallel kernels (0 = all cores).
    threads: usize,
    cache: Mutex<RowCache>,
}

impl DiskGramCov {
    /// Open the operator over an existing, validated manifest.
    ///
    /// `cache_mb` bounds the Σ-row LRU cache (0 disables caching);
    /// `threads` is the worker count for shard-parallel kernels
    /// (0 = all cores).
    pub fn new(dir: &Path, man: ShardManifest, cache_mb: usize, threads: usize) -> DiskGramCov {
        let cap_rows = crate::covop::row_cache_cap(cache_mb, man.nhat);
        DiskGramCov {
            dir: dir.to_path_buf(),
            man,
            threads,
            cache: Mutex::new(RowCache::new(cap_rows)),
        }
    }

    /// Open from a cache directory and key: `Ok(None)` when the cache
    /// does not exist yet, `Err` on a corrupt or stale manifest.
    pub fn open(
        dir: &Path,
        key: &ShardCacheKey,
        cache_mb: usize,
        threads: usize,
    ) -> Result<Option<DiskGramCov>, crate::error::LsspcaError> {
        Ok(shardcache::open(dir, key)?.map(|man| DiskGramCov::new(dir, man, cache_mb, threads)))
    }

    /// The manifest this operator streams from.
    pub fn manifest(&self) -> &ShardManifest {
        &self.man
    }

    /// Number of shards on disk.
    pub fn num_shards(&self) -> usize {
        self.man.shards.len()
    }

    /// Stored nonzeros of the reduced term matrix.
    pub fn nnz(&self) -> usize {
        self.man.nnz
    }

    /// `(cache hits, cache misses)` so far — the same capacity-planning
    /// telemetry [`GramCov::cache_stats`] reports.
    pub fn cache_stats(&self) -> (u64, u64) {
        let c = self.cache.lock().unwrap();
        (c.hits, c.misses)
    }

    /// Rows the Σ-row cache can hold under the configured budget.
    pub fn cache_capacity_rows(&self) -> usize {
        self.cache.lock().unwrap().cap_rows
    }

    /// Load and verify shard `s`, panicking with the underlying error on
    /// I/O or integrity failure (see the module docs' failure model).
    fn shard(&self, s: usize) -> ShardBlock {
        match shardcache::load_shard(&self.dir, &self.man, s) {
            Ok(b) => b,
            Err(e) => panic!("disk covariance backend: {e}"),
        }
    }

    /// Index of the shard holding reduced column `j`.
    fn shard_of(&self, j: usize) -> usize {
        debug_assert!(j < self.man.nhat);
        match self.man.shards.binary_search_by(|m| {
            if j < m.col_start {
                std::cmp::Ordering::Greater
            } else if j >= m.col_start + m.ncols {
                std::cmp::Ordering::Less
            } else {
                std::cmp::Ordering::Equal
            }
        }) {
            Ok(s) => s,
            Err(_) => panic!("disk covariance backend: no shard covers column {j}"),
        }
    }

    /// `ax = A x` — the first half of every Gram action, swept shard by
    /// shard in column order so each document's terms accumulate in the
    /// CSR row's own ascending-column order (bitwise the in-memory
    /// `CsrMatrix::matvec_into`). Shards of a wave are *loaded* in
    /// parallel; the fold itself is a strict column-order scatter.
    ///
    /// Requires `ax` pre-zeroed (both callers hand it a fresh buffer —
    /// re-zeroing the full m-length vector on every probe was pure
    /// overhead). Only shards overlapping the *active* (nonzero) columns
    /// of `x` are loaded at all: a λ-search quad form on a
    /// cardinality-k loading touches k columns, so whole shards — and
    /// their disk reads — drop out. Skipping is bitwise-neutral: a
    /// skipped column contributes only `±0.0` terms, which cannot change
    /// a partial sum seeded at `+0.0` (see
    /// [`crate::data::CscMatrix::scatter_matvec_into`], the in-memory
    /// kernel this sweep mirrors).
    fn stream_ax(&self, x: &[f64], ax: &mut [f64]) {
        assert_eq!(x.len(), self.man.nhat);
        assert_eq!(ax.len(), self.man.rows);
        debug_assert!(ax.iter().all(|&v| v == 0.0), "ax must start zeroed");
        let active: Vec<usize> = (0..self.man.shards.len())
            .filter(|&s| {
                let m = &self.man.shards[s];
                x[m.col_start..m.col_start + m.ncols].iter().any(|&v| v != 0.0)
            })
            .collect();
        let nactive = active.len();
        let wave = resolve_threads(self.threads).min(nactive.max(1));
        let mut start = 0;
        while start < nactive {
            let count = wave.min(nactive - start);
            let blocks = par_map_indexed(self.threads, count, |k| self.shard(active[start + k]));
            for b in &blocks {
                for c in 0..b.ncols {
                    let xc = x[b.col_start + c];
                    if xc == 0.0 {
                        continue;
                    }
                    for (d, v) in b.col(c) {
                        ax[d] += v * xc;
                    }
                }
            }
            start += count;
        }
    }

    /// One shard's slice of Σ row `j`: merge-dot of `col_j` against each
    /// of the shard's columns over ascending doc ids (GramCov's per-k
    /// order), then centering.
    fn row_part(&self, b: &ShardBlock, col_j: &[(u32, f64)], mu_j: f64) -> Vec<f64> {
        let inv_m = 1.0 / self.man.total_docs.max(1) as f64;
        let mut part = vec![0.0; b.ncols];
        for (c, o) in part.iter_mut().enumerate() {
            let mut acc = 0.0;
            let (lo, hi) = (b.colptr[c], b.colptr[c + 1]);
            let (mut a, mut kq) = (0usize, lo);
            while a < col_j.len() && kq < hi {
                let (da, dk) = (col_j[a].0, b.rowidx[kq]);
                match da.cmp(&dk) {
                    std::cmp::Ordering::Less => a += 1,
                    std::cmp::Ordering::Greater => kq += 1,
                    std::cmp::Ordering::Equal => {
                        acc += col_j[a].1 * b.values[kq];
                        a += 1;
                        kq += 1;
                    }
                }
            }
            let k = b.col_start + c;
            *o = acc * inv_m - mu_j * self.man.mean[k];
        }
        part
    }

    /// Compute Σ row `j` from the shards: a sorted-merge dot of column
    /// `j` against every column, shard-parallel over disjoint output
    /// ranges, then centered — the same value sequence as
    /// [`GramCov`]'s row kernel, bit for bit. The home shard (already
    /// decoded to extract column `j`) is consumed inline rather than
    /// loaded a second time.
    fn compute_row(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.man.nhat);
        let home_idx = self.shard_of(j);
        let home = self.shard(home_idx);
        let local = j - home.col_start;
        let col_j: Vec<(u32, f64)> =
            home.col(local).map(|(d, v)| (d as u32, v)).collect();
        let mu_j = self.man.mean[j];
        let home_part = self.row_part(&home, &col_j, mu_j);
        out[home.col_start..home.col_start + home_part.len()].copy_from_slice(&home_part);
        drop(home);
        let nshards = self.man.shards.len();
        let parts = par_map_indexed(self.threads, nshards, |s| {
            if s == home_idx {
                return None;
            }
            let b = self.shard(s);
            Some((b.col_start, self.row_part(&b, &col_j, mu_j)))
        });
        for (col_start, part) in parts.into_iter().flatten() {
            out[col_start..col_start + part.len()].copy_from_slice(&part);
        }
    }

    /// Gather via the row cache — the shared
    /// [`crate::covop::cached_gather_with`] protocol with this backend's
    /// shard-streaming row kernel.
    fn cached_gather(&self, j: usize, idx: Option<&[usize]>, out: &mut [f64]) {
        crate::covop::cached_gather_with(&self.cache, self.man.nhat, j, idx, out, |j, row| {
            self.compute_row(j, row)
        });
    }
}

impl CovOp for DiskGramCov {
    fn n(&self) -> usize {
        self.man.nhat
    }

    fn diag(&self, j: usize) -> f64 {
        self.man.diag[j]
    }

    fn row_into(&self, j: usize, out: &mut [f64]) {
        self.cached_gather(j, None, out);
    }

    fn row_gather(&self, j: usize, idx: &[usize], out: &mut [f64]) {
        self.cached_gather(j, Some(idx), out);
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.man.nhat);
        assert_eq!(y.len(), self.man.nhat);
        // ax = A x, then y[c0..c1) = A_sᵀ ax per shard (disjoint ranges,
        // computed in parallel, stitched in shard order), then centering
        // — the same three folds as GramCov::matvec, in the same order.
        let mut ax = vec![0.0; self.man.rows];
        self.stream_ax(x, &mut ax);
        let nshards = self.man.shards.len();
        let parts = par_map_indexed(self.threads, nshards, |s| {
            let b = self.shard(s);
            let mut part = vec![0.0; b.ncols];
            for (c, o) in part.iter_mut().enumerate() {
                let mut acc = 0.0;
                for (d, v) in b.col(c) {
                    let a = ax[d];
                    if a == 0.0 {
                        continue;
                    }
                    acc += v * a;
                }
                *o = acc;
            }
            (b.col_start, part)
        });
        for (col_start, part) in parts {
            y[col_start..col_start + part.len()].copy_from_slice(&part);
        }
        let inv_m = 1.0 / self.man.total_docs.max(1) as f64;
        let mux = crate::linalg::vec::dot(&self.man.mean, x);
        for (yk, &mu_k) in y.iter_mut().zip(&self.man.mean) {
            *yk = *yk * inv_m - mu_k * mux;
        }
    }

    fn quad_form(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.man.nhat);
        // xᵀΣx = ‖Ax‖²/m − (μᵀx)², streamed — GramCov::quad_form's folds.
        let mut ax = vec![0.0; self.man.rows];
        self.stream_ax(x, &mut ax);
        // Same 4-lane reduction as `GramCov::quad_form` — the two
        // backends must stay bitwise-paired (pinned below).
        let ssq = crate::linalg::vec::dot(&ax, &ax);
        let mux = crate::linalg::vec::dot(&self.man.mean, x);
        ssq / self.man.total_docs.max(1) as f64 - mux * mux
    }
}

/// Convenience used by benches and tests: build an in-memory [`GramCov`]
/// and a [`DiskGramCov`] over the **same** reduced CSR, writing (or
/// reusing) the shard cache under `dir`.
pub fn disk_twin_of(
    csr: &crate::data::CsrMatrix,
    total_docs: u64,
    dir: &Path,
    key: &ShardCacheKey,
    shard_bytes: usize,
    cache_mb: usize,
    threads: usize,
) -> Result<(GramCov, DiskGramCov), crate::error::LsspcaError> {
    let man = shardcache::write(dir, key, csr, total_docs, shard_bytes)?;
    let disk = DiskGramCov::new(dir, man, cache_mb, threads);
    Ok((GramCov::new(csr.clone(), total_docs, cache_mb), disk))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TripletMatrix;
    use crate::util::check::property;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize) -> crate::data::CsrMatrix {
        let mut t = TripletMatrix::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if rng.bool(0.35) {
                    t.push(r, c, (1 + rng.below(5)) as f64);
                }
            }
        }
        t.to_csr()
    }

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_covdisk_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn prop_disk_matches_gram_bitwise() {
        property("DiskGramCov == GramCov bitwise", 8, |rng| {
            let rows = rng.range(3, 60);
            let cols = rng.range(2, 18);
            let csr = random_csr(rng, rows, cols);
            let dir = tmpdir("bw");
            let key = ShardCacheKey {
                corpus_digest: rng.below(1 << 30) as u64,
                elim_digest: 99,
            };
            // tiny shard budget → several shards; tiny cache → eviction
            let (gram, disk) =
                disk_twin_of(&csr, rows as u64 + 1, &dir, &key, 200, 1, 2).unwrap();
            assert_eq!(CovOp::n(&disk), cols);
            let mut rg = vec![0.0; cols];
            let mut rd = vec![0.0; cols];
            for j in 0..cols {
                if disk.diag(j).to_bits() != gram.diag(j).to_bits() {
                    return Err(format!("diag {j} differs"));
                }
                gram.row_into(j, &mut rg);
                disk.row_into(j, &mut rd);
                for k in 0..cols {
                    if rg[k].to_bits() != rd[k].to_bits() {
                        return Err(format!("row {j} col {k}: {} vs {}", rg[k], rd[k]));
                    }
                }
            }
            let x: Vec<f64> = (0..cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let (mut yg, mut yd) = (vec![0.0; cols], vec![0.0; cols]);
            gram.matvec(&x, &mut yg);
            disk.matvec(&x, &mut yd);
            if yg.iter().zip(&yd).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err("matvec differs".into());
            }
            if gram.quad_form(&x).to_bits() != disk.quad_form(&x).to_bits() {
                return Err("quad_form differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn disk_deterministic_across_threads_and_cache_sizes() {
        let mut rng = Rng::seed_from(41);
        let csr = random_csr(&mut rng, 80, 12);
        let dir = tmpdir("det");
        let key = ShardCacheKey { corpus_digest: 1, elim_digest: 2 };
        let man = shardcache::write(&dir, &key, &csr, 80, 300).unwrap();
        let x: Vec<f64> = (0..12).map(|_| rng.gauss()).collect();
        let mut reference: Option<(Vec<u64>, Vec<u64>)> = None;
        for (threads, cache_mb) in [(1, 0), (1, 4), (4, 1), (3, 16)] {
            let disk = DiskGramCov::new(&dir, man.clone(), cache_mb, threads);
            let mut y = vec![0.0; 12];
            disk.matvec(&x, &mut y);
            let ybits: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
            let mut row = vec![0.0; 12];
            let mut rbits = Vec::new();
            for j in 0..12 {
                disk.row_into(j, &mut row);
                rbits.extend(row.iter().map(|v| v.to_bits()));
                // repeated gather (cached or not) returns the same bits
                let mut again = vec![0.0; 12];
                disk.row_into(j, &mut again);
                assert_eq!(row, again);
            }
            match &reference {
                None => reference = Some((ybits, rbits)),
                Some((wy, wr)) => {
                    assert_eq!(&ybits, wy, "threads={threads} cache={cache_mb}");
                    assert_eq!(&rbits, wr, "threads={threads} cache={cache_mb}");
                }
            }
        }
    }

    #[test]
    fn open_roundtrip_and_missing() {
        let mut rng = Rng::seed_from(42);
        let csr = random_csr(&mut rng, 30, 6);
        let dir = tmpdir("open");
        let key = ShardCacheKey { corpus_digest: 10, elim_digest: 20 };
        assert!(DiskGramCov::open(&dir, &key, 4, 1).unwrap().is_none());
        shardcache::write(&dir, &key, &csr, 30, 1 << 20).unwrap();
        let disk = DiskGramCov::open(&dir, &key, 4, 1).unwrap().expect("cache hit");
        assert_eq!(disk.nnz(), csr.nnz());
        assert!(disk.num_shards() >= 1);
        assert!(disk.cache_capacity_rows() > 0);
        let (h, m) = disk.cache_stats();
        assert_eq!((h, m), (0, 0));
    }
}
