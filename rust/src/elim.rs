//! Safe feature elimination — Theorem 2.1 of the paper.
//!
//! With `Σ = AᵀA` (so `Σ_ii = aᵢᵀaᵢ` is feature `i`'s energy), the sparse
//! PCA problem (2) satisfies
//!
//! ```text
//! ψ = max_{‖ξ‖₂=1} Σᵢ ((aᵢᵀξ)² − λ)₊
//! ```
//!
//! and feature `i` is absent from every optimal support whenever
//! `(aᵢᵀξ)² ≤ aᵢᵀaᵢ = Σ_ii ≤ λ`. So features with `Σ_ii < λ` can be
//! removed *before* solving — safely, i.e. without changing the optimum.
//! On data with rapidly decaying ranked variances this collapses the
//! problem by orders of magnitude (paper: 102,660 → ≤ 500).

use crate::moments::FeatureVariances;

/// Result of applying the elimination test at one λ.
#[derive(Clone, Debug)]
pub struct SafeElimination {
    /// λ used by the test.
    pub lambda: f64,
    /// Original feature count n.
    pub original: usize,
    /// Kept (surviving) original feature indices, in decreasing-variance
    /// order — the order the reduced covariance is assembled in.
    pub kept: Vec<usize>,
    /// The survivors' variances, aligned with `kept`.
    pub kept_variances: Vec<f64>,
}

impl SafeElimination {
    /// Apply the test: keep exactly the features with `Σ_ii > λ`
    /// (strict, per Thm 2.1's "absent if Σ_ii ≤ λ" contrapositive — we
    /// keep when the variance strictly exceeds λ).
    ///
    /// `max_keep` optionally caps the reduced size by keeping only the
    /// highest-variance survivors; a cap makes the reduction *heuristic*
    /// beyond the cap (recorded in [`SafeElimination::capped`]).
    pub fn apply(variances: &[f64], lambda: f64, max_keep: Option<usize>) -> SafeElimination {
        let mut ranked: Vec<(usize, f64)> = variances
            .iter()
            .copied()
            .enumerate()
            .filter(|&(_, v)| v > lambda)
            .collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        if let Some(cap) = max_keep {
            ranked.truncate(cap);
        }
        SafeElimination {
            lambda,
            original: variances.len(),
            kept: ranked.iter().map(|&(i, _)| i).collect(),
            kept_variances: ranked.iter().map(|&(_, v)| v).collect(),
        }
    }

    /// Apply using centered variances from a moment pass.
    pub fn from_variances(
        fv: &FeatureVariances,
        lambda: f64,
        max_keep: Option<usize>,
    ) -> SafeElimination {
        Self::apply(&fv.variance, lambda, max_keep)
    }

    /// Reduced problem size n̂.
    pub fn reduced(&self) -> usize {
        self.kept.len()
    }

    /// Reduction factor n / n̂ (∞ if everything was eliminated).
    pub fn reduction_factor(&self) -> f64 {
        if self.kept.is_empty() {
            f64::INFINITY
        } else {
            self.original as f64 / self.kept.len() as f64
        }
    }

    /// Whether a `max_keep` cap actually truncated the survivor set —
    /// i.e. the reduction is no longer purely "safe".
    pub fn capped(&self, variances: &[f64]) -> bool {
        let survivors = variances.iter().filter(|&&v| v > self.lambda).count();
        survivors > self.kept.len()
    }

    /// Map a reduced-space vector back to the full feature space.
    pub fn lift(&self, reduced_vec: &[f64]) -> Vec<f64> {
        assert_eq!(reduced_vec.len(), self.kept.len());
        let mut full = vec![0.0; self.original];
        for (r, &orig) in self.kept.iter().enumerate() {
            full[orig] = reduced_vec[r];
        }
        full
    }

    /// Position of an original feature in the reduced index space.
    pub fn position_of(&self, original_idx: usize) -> Option<usize> {
        self.kept.iter().position(|&k| k == original_idx)
    }
}

/// The λ → n̂ curve: for each λ in `lambdas`, the number of surviving
/// features. Monotone non-increasing in λ. This is the quantitative form
/// of the paper's headline "150∼200 times smaller" observation (E5).
pub fn lambda_survivor_curve(variances: &[f64], lambdas: &[f64]) -> Vec<(f64, usize)> {
    // Sort variances descending once; each λ is then a binary search.
    let mut sorted = variances.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    lambdas
        .iter()
        .map(|&lam| {
            // count of entries strictly greater than lam
            let cnt = sorted.partition_point(|&v| v > lam);
            (lam, cnt)
        })
        .collect()
}

/// Smallest λ that leaves at most `target` survivors (from the sorted
/// variance profile). Useful to seed the λ-search for a target cardinality.
pub fn lambda_for_survivors(variances: &[f64], target: usize) -> f64 {
    let mut sorted = variances.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    if target >= sorted.len() {
        return 0.0;
    }
    // keeping features with v > λ: λ = variance of feature `target` keeps
    // exactly the strictly-larger ones (ties collapse together).
    sorted[target]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{ensure, property};

    #[test]
    fn keeps_only_above_lambda() {
        let v = [0.1, 5.0, 0.3, 2.0, 0.05];
        let e = SafeElimination::apply(&v, 0.25, None);
        assert_eq!(e.kept, vec![1, 3, 2]); // sorted by decreasing variance
        assert_eq!(e.reduced(), 3);
        assert!((e.reduction_factor() - 5.0 / 3.0).abs() < 1e-12);
        assert!(!e.capped(&v));
    }

    #[test]
    fn cap_truncates_and_flags() {
        let v = [1.0, 2.0, 3.0, 4.0];
        let e = SafeElimination::apply(&v, 0.5, Some(2));
        assert_eq!(e.kept, vec![3, 2]);
        assert!(e.capped(&v));
    }

    #[test]
    fn strict_threshold() {
        let v = [1.0, 2.0];
        // Σ_ii == λ is eliminated (test is (aᵢᵀξ)² ≤ λ ⇒ absent)
        let e = SafeElimination::apply(&v, 1.0, None);
        assert_eq!(e.kept, vec![1]);
    }

    #[test]
    fn lift_roundtrip() {
        let v = [0.0, 3.0, 0.0, 2.0];
        let e = SafeElimination::apply(&v, 1.0, None);
        let full = e.lift(&[0.7, -0.7]);
        assert_eq!(full, vec![0.0, 0.7, 0.0, -0.7]);
        assert_eq!(e.position_of(3), Some(1));
        assert_eq!(e.position_of(0), None);
    }

    #[test]
    fn prop_curve_monotone_and_consistent() {
        property("λ→n̂ curve monotone, matches direct count", 25, |rng| {
            let n = rng.range(1, 100);
            let v: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let mut lams: Vec<f64> = (0..10).map(|_| rng.range_f64(0.0, 12.0)).collect();
            lams.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let curve = lambda_survivor_curve(&v, &lams);
            for w in curve.windows(2) {
                ensure(w[0].1 >= w[1].1, "curve must be non-increasing")?;
            }
            for &(lam, cnt) in &curve {
                let direct = v.iter().filter(|&&x| x > lam).count();
                ensure(cnt == direct, format!("count mismatch at λ={lam}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_lambda_for_survivors_bound() {
        property("lambda_for_survivors leaves ≤ target", 25, |rng| {
            let n = rng.range(1, 60);
            let v: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 5.0)).collect();
            let target = rng.below(n + 2);
            let lam = lambda_for_survivors(&v, target);
            let kept = v.iter().filter(|&&x| x > lam).count();
            // The intended bound, stated directly: the survivor count never
            // exceeds the target. For target < n, λ is the (target+1)-th
            // largest variance, so at most `target` entries are strictly
            // larger (ties collapse to fewer). For target ≥ n, λ = 0 keeps
            // at most n ≤ target.
            ensure(kept <= target, format!("kept={kept} > target={target} (λ={lam})"))?;
            ensure(lam >= 0.0, "λ must be non-negative")?;
            Ok(())
        });
    }

    #[test]
    fn empty_input() {
        let e = SafeElimination::apply(&[], 0.1, None);
        assert_eq!(e.reduced(), 0);
        assert!(e.reduction_factor().is_infinite());
    }
}
