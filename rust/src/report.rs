//! Experiment reporting: markdown tables (the paper's Tables 1–2 format),
//! CSV series, and helpers shared by the benches and examples.

use std::fmt::Write as _;

use crate::data::Vocab;
use crate::solver::extract::SparsePc;

/// Render a markdown table from a header and rows.
pub fn markdown_table(header: &[String], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut out = String::new();
    let _ = writeln!(out, "| {} |", header.join(" | "));
    let _ = writeln!(out, "|{}", "---|".repeat(cols));
    for row in rows {
        let mut cells = row.clone();
        cells.resize(cols, String::new());
        let _ = writeln!(out, "| {} |", cells.join(" | "));
    }
    out
}

/// Render the paper's topic-table format: one column per PC, one word per
/// row (Tables 1 and 2).
pub fn topic_table(pcs: &[SparsePc], vocab: &Vocab, kept_to_orig: Option<&[usize]>) -> String {
    let header: Vec<String> = pcs
        .iter()
        .enumerate()
        .map(|(k, pc)| format!("{} PC ({} words)", ordinal(k + 1), pc.cardinality()))
        .collect();
    let depth = pcs.iter().map(|pc| pc.cardinality()).max().unwrap_or(0);
    let mut rows = Vec::with_capacity(depth);
    for r in 0..depth {
        let row: Vec<String> = pcs
            .iter()
            .map(|pc| {
                pc.support
                    .get(r)
                    .map(|&i| {
                        let orig = kept_to_orig.map_or(i, |map| map[i]);
                        vocab.word(orig)
                    })
                    .unwrap_or_default()
            })
            .collect();
        rows.push(row);
    }
    markdown_table(&header, &rows)
}

fn ordinal(k: usize) -> String {
    let suffix = match (k % 10, k % 100) {
        (1, 11) | (2, 12) | (3, 13) => "th",
        (1, _) => "st",
        (2, _) => "nd",
        (3, _) => "rd",
        _ => "th",
    };
    format!("{k}{suffix}")
}

/// Write `(x, y)` series as CSV.
pub fn csv_series(header: (&str, &str), pts: &[(f64, f64)]) -> String {
    let mut out = format!("{},{}\n", header.0, header.1);
    for (x, y) in pts {
        let _ = writeln!(out, "{x},{y}");
    }
    out
}

/// Save text to a file, creating parent directories.
pub fn save(path: &std::path::Path, text: &str) -> Result<(), crate::error::LsspcaError> {
    use crate::error::LsspcaError;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| LsspcaError::io_at(dir, format!("mkdir: {e}")))?;
    }
    std::fs::write(path, text).map_err(|e| LsspcaError::io_at(path, format!("write: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordinals() {
        assert_eq!(ordinal(1), "1st");
        assert_eq!(ordinal(2), "2nd");
        assert_eq!(ordinal(3), "3rd");
        assert_eq!(ordinal(4), "4th");
        assert_eq!(ordinal(11), "11th");
        assert_eq!(ordinal(21), "21st");
    }

    #[test]
    fn markdown_shape() {
        let t = markdown_table(
            &["a".into(), "b".into()],
            &[vec!["1".into()], vec!["2".into(), "3".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[1].contains("---|---"));
        assert!(lines[2].ends_with("| 1 |  |"));
    }

    #[test]
    fn topic_table_uses_vocab_and_mapping() {
        let vocab = Vocab::new(vec!["zero".into(), "one".into(), "two".into(), "three".into()]);
        let pc = SparsePc {
            vector: vec![0.9, 0.44, 0.0],
            support: vec![0, 1],
            z_eigenvalue: 1.0,
        };
        // reduced index 0 → original 3, 1 → original 1
        let table = topic_table(&[pc], &vocab, Some(&[3, 1]));
        assert!(table.contains("three"));
        assert!(table.contains("one"));
        assert!(table.contains("1st PC (2 words)"));
    }

    #[test]
    fn csv_format() {
        let s = csv_series(("t", "obj"), &[(0.5, 1.25)]);
        assert_eq!(s, "t,obj\n0.5,1.25\n");
    }
}
