//! Checkpointing for the expensive streaming pass.
//!
//! At PubMed scale (8.2M docs, 7.8 GB on disk) the variance pass is the
//! dominant I/O cost, and it is λ-independent: every λ-search, every
//! re-run with a different target cardinality, reuses the same per-feature
//! variances. This module persists a [`FeatureVariances`] to a compact
//! binary file keyed by a corpus fingerprint, so repeated pipeline runs
//! skip the pass entirely (`corpus.cache_dir` in the config).
//!
//! Format (little-endian): magic "LSPV", u32 version, u64 key hash,
//! u64 docs, u64 n, then 3n f64 (variance, mean, second_moment), then a
//! trailing xor-fold checksum of the payload.

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::error::LsspcaError;
use crate::moments::FeatureVariances;
use crate::util::{atomic_write, faultinject, retry};

const MAGIC: &[u8; 4] = b"LSPV";
const VERSION: u32 = 1;

/// Fingerprint of the corpus a checkpoint belongs to (FNV-1a over a
/// caller-supplied identity string: preset+docs+vocab+seed, or input path
/// + file length).
pub fn corpus_key(identity: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in identity.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

use crate::util::xor_fold_checksum as checksum;

/// Checkpoint file path for a key inside a cache directory.
pub fn path_for(cache_dir: &Path, key: u64) -> PathBuf {
    cache_dir.join(format!("variances_{key:016x}.lspv"))
}

/// Save a variance checkpoint. Failures are [`LsspcaError::Cache`] —
/// an unwritable cache is a cache-layer condition the pipeline degrades
/// around, not a hard I/O failure of the run itself.
///
/// The write is crash-atomic (tmp + fsync + rename, see
/// [`crate::util::atomic_write`]): a kill mid-save can never replace a
/// valid checkpoint with a torn one. Transient write failures retry
/// under the process [`retry::policy`]; exhaustion surfaces as a
/// *transient* cache error ([`LsspcaError::is_transient`]).
pub fn save(path: &Path, key: u64, fv: &FeatureVariances) -> Result<(), LsspcaError> {
    let cache_err = |what: &str, e: std::io::Error| {
        LsspcaError::cache(format!("checkpoint {}: {what}: {e}", path.display()))
    };
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| cache_err("mkdir", e))?;
    }
    let n = fv.variance.len();
    assert_eq!(fv.mean.len(), n);
    assert_eq!(fv.second_moment.len(), n);
    let mut bytes = Vec::with_capacity(16 + 24 + 24 * n);
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&VERSION.to_le_bytes());
    bytes.extend_from_slice(&key.to_le_bytes());
    bytes.extend_from_slice(&fv.docs.to_le_bytes());
    bytes.extend_from_slice(&(n as u64).to_le_bytes());
    for series in [&fv.variance, &fv.mean, &fv.second_moment] {
        for v in series.iter() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    let sum = checksum(&bytes[8..]);
    bytes.extend_from_slice(&sum.to_le_bytes());
    retry::with_retry(&retry::policy(), || atomic_write(path, "checkpoint", &bytes)).map_err(|e| {
        let msg = e.describe(&format!("checkpoint {}: write", path.display()));
        if e.transient { LsspcaError::cache_transient(msg) } else { LsspcaError::cache(msg) }
    })
}

/// Load a checkpoint; verifies magic, version, key, checksum **and** the
/// feature dimension against the live corpus when `expected_n` is given.
/// Returns `Ok(None)` when the file does not exist, `Err` on any
/// corruption or mismatch (a corrupt or mismatched cache must never be
/// silently used — before the dimension check, a checkpoint whose key
/// happened to collide with a corpus of different vocabulary size would
/// pass the hash test and then index out of bounds deep in elimination).
pub fn load(
    path: &Path,
    key: u64,
    expected_n: Option<usize>,
) -> Result<Option<FeatureVariances>, LsspcaError> {
    let buf = match retry::with_retry(&retry::policy(), || {
        let f = std::fs::File::open(path)?;
        let mut r = faultinject::wrap_read("checkpoint", f);
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        Ok(buf)
    }) {
        Ok(buf) => buf,
        Err(e) if e.error.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            let msg = e.describe(&format!("read {}", path.display()));
            return Err(if e.transient {
                LsspcaError::cache_transient(msg)
            } else {
                LsspcaError::cache(msg)
            });
        }
    };
    if buf.len() < 8 + 24 + 8 || &buf[..4] != MAGIC {
        return Err(LsspcaError::cache("checkpoint: bad magic or truncated header"));
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(LsspcaError::cache(format!("checkpoint: version {version}, want {VERSION}")));
    }
    let payload = &buf[8..buf.len() - 8];
    let stored_sum = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored_sum {
        return Err(LsspcaError::cache("checkpoint: checksum mismatch (corrupt file)"));
    }
    let rd_u64 = |o: usize| u64::from_le_bytes(payload[o..o + 8].try_into().unwrap());
    let stored_key = rd_u64(0);
    if stored_key != key {
        return Err(LsspcaError::cache(format!(
            "checkpoint: corpus key mismatch ({stored_key:#x} vs {key:#x}) — stale cache"
        )));
    }
    let docs = rd_u64(8);
    let n = rd_u64(16) as usize;
    if payload.len() != 24 + 24 * n {
        return Err(LsspcaError::cache("checkpoint: payload size mismatch"));
    }
    if let Some(want) = expected_n {
        if n != want {
            return Err(LsspcaError::cache(format!(
                "checkpoint: dimension mismatch (file has n={n}, corpus has n={want}) — \
                 stale or foreign cache"
            )));
        }
    }
    let read_series = |idx: usize| -> Vec<f64> {
        let base = 24 + idx * 8 * n;
        (0..n)
            .map(|i| f64::from_le_bytes(payload[base + 8 * i..base + 8 * i + 8].try_into().unwrap()))
            .collect()
    };
    Ok(Some(FeatureVariances {
        variance: read_series(0),
        mean: read_series(1),
        second_moment: read_series(2),
        docs,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> FeatureVariances {
        let mut rng = Rng::seed_from(seed);
        FeatureVariances {
            variance: (0..n).map(|_| rng.range_f64(0.0, 5.0)).collect(),
            mean: (0..n).map(|_| rng.gauss()).collect(),
            second_moment: (0..n).map(|_| rng.range_f64(0.0, 30.0)).collect(),
            docs: 12345,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsspca_ckpt_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let fv = sample(300, 1);
        let key = corpus_key("nytimes:300");
        let p = tmp("rt.lspv");
        save(&p, key, &fv).unwrap();
        let got = load(&p, key, Some(300)).unwrap().unwrap();
        assert_eq!(got.docs, fv.docs);
        assert_eq!(got.variance, fv.variance);
        assert_eq!(got.mean, fv.mean);
        assert_eq!(got.second_moment, fv.second_moment);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_none() {
        assert!(load(&tmp("nope.lspv"), 1, None).unwrap().is_none());
    }

    #[test]
    fn key_mismatch_rejected() {
        let fv = sample(10, 2);
        let p = tmp("key.lspv");
        save(&p, corpus_key("a"), &fv).unwrap();
        let err = load(&p, corpus_key("b"), None).unwrap_err();
        assert!(matches!(err, LsspcaError::Cache { .. }));
        let err = err.to_string();
        assert!(err.contains("key mismatch"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn dimension_mismatch_rejected() {
        // Regression: a checkpoint passing the key test but holding a
        // different vocabulary size must be rejected here, not surface as
        // an index panic downstream in elimination.
        let fv = sample(50, 9);
        let key = corpus_key("dim");
        let p = tmp("dim.lspv");
        save(&p, key, &fv).unwrap();
        let err = load(&p, key, Some(60)).unwrap_err().to_string();
        assert!(err.contains("dimension mismatch"), "{err}");
        assert!(err.contains("n=50") && err.contains("n=60"), "{err}");
        // the matching dimension (and the no-expectation path) still load
        assert!(load(&p, key, Some(50)).unwrap().is_some());
        assert!(load(&p, key, None).unwrap().is_some());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corruption_rejected() {
        let fv = sample(50, 3);
        let key = corpus_key("c");
        let p = tmp("corrupt.lspv");
        save(&p, key, &fv).unwrap();
        // flip one payload byte
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p, key, None).unwrap_err();
        assert!(matches!(err, LsspcaError::Cache { .. }));
        assert!(err.to_string().contains("checksum"), "{err}");
        // truncation
        std::fs::write(&p, &bytes[..bytes.len() / 3]).unwrap();
        assert!(load(&p, key, None).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn distinct_identities_distinct_keys() {
        assert_ne!(corpus_key("nytimes:50000:30000:1"), corpus_key("nytimes:50000:30000:2"));
    }
}
