//! Integration: the `/v1` serving layer over real sockets — keep-alive
//! and pipelining on one connection, multi-model registry routing, hot
//! reload under concurrent load (zero 5xx during the swap), overload
//! shedding, legacy/v1 bitwise body parity, malformed-request handling,
//! and fault injection against the reload watcher.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lsspca::prelude::*;
use lsspca::util::faultinject::{self, FaultPlan};
use lsspca::util::json::Json;

// ---------------------------------------------------------------------------
// Helpers: tiny models + a keep-alive-aware HTTP/1.1 client
// ---------------------------------------------------------------------------

/// A 3-term, 2-PC model whose PC1 score of `{"words": [[3, 1]]}` is
/// exactly `w` — lets each test pin which model answered.
fn model_with_weight(name: &str, w: f64) -> Model {
    Model {
        corpus_name: name.into(),
        num_docs: 10,
        n_features: 100,
        vocab_hash: 0,
        seed: 1,
        elim_lambda: 0.2,
        kept: vec![3, 8, 15],
        kept_means: vec![0.0, 0.0, 0.0],
        kept_stds: vec![1.0, 1.0, 1.0],
        kept_words: vec!["alpha".into(), "beta".into(), "gamma".into()],
        pcs: vec![
            ModelPc {
                lambda: 0.5,
                phi: 1.0,
                explained_variance: 1.0,
                loadings: vec![(3, w), (8, 0.8)],
            },
            ModelPc {
                lambda: 0.5,
                phi: 0.7,
                explained_variance: 0.7,
                loadings: vec![(15, 1.0)],
            },
        ],
    }
}

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_srv1_{}_{name}", std::process::id()));
    p
}

struct Resp {
    status: u16,
    head: String,
    body: Vec<u8>,
}

impl Resp {
    fn json(&self) -> Json {
        Json::parse(std::str::from_utf8(&self.body).unwrap_or("")).unwrap_or(Json::Null)
    }

    fn header(&self, name: &str) -> Option<String> {
        let want = name.to_ascii_lowercase();
        self.head.lines().find_map(|l| {
            let (n, v) = l.split_once(':')?;
            (n.to_ascii_lowercase() == want).then(|| v.trim().to_string())
        })
    }

    fn score0(&self) -> f64 {
        self.json().get("scores").expect("scores").as_array().expect("array")[0]
            .as_f64()
            .expect("f64")
    }
}

/// Read exactly one response off a (possibly keep-alive) stream: head to
/// the blank line, then `Content-Length` body bytes.
fn read_resp(s: &mut TcpStream) -> Resp {
    let mut head = Vec::new();
    let mut b = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        match s.read(&mut b) {
            Ok(0) => panic!("eof mid-head: {:?}", String::from_utf8_lossy(&head)),
            Ok(_) => head.push(b[0]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => panic!("reading head: {e}"),
        }
        assert!(head.len() < 64 * 1024, "unterminated response head");
    }
    let head = String::from_utf8(head).unwrap();
    let len: usize = head
        .lines()
        .find_map(|l| {
            let (n, v) = l.split_once(':')?;
            n.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().unwrap())
        })
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    let status = head.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap();
    Resp { status, head, body }
}

/// Write one request on an existing keep-alive stream.
fn send(s: &mut TcpStream, method: &str, path: &str, body: &str) {
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
}

/// One-shot request on a fresh connection (`Connection: close`).
fn req(addr: SocketAddr, method: &str, path: &str, body: &str) -> Resp {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    read_resp(&mut s)
}

/// Raw bytes on a fresh connection; returns the single response.
fn raw(addr: SocketAddr, bytes: &[u8]) -> Resp {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(bytes).unwrap();
    read_resp(&mut s)
}

fn start(server: Server) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let addr = server.local_addr();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().unwrap());
    (addr, handle, join)
}

// ---------------------------------------------------------------------------
// Keep-alive + pipelining
// ---------------------------------------------------------------------------

#[test]
fn keep_alive_connection_pipelines_requests() {
    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(2)
        .model(model_with_weight("pipeline", 0.6))
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);

    // Three requests written back-to-back before any read: the server
    // must answer all three, in order, on the one connection.
    let body = r#"{"words": [[3, 1]]}"#;
    let mut batch = Vec::new();
    for _ in 0..2 {
        batch.extend_from_slice(
            format!(
                "POST /v1/models/default/score HTTP/1.1\r\nHost: t\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        );
    }
    batch.extend_from_slice(b"GET /v1/healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&batch).unwrap();
    for i in 0..2 {
        let r = read_resp(&mut s);
        assert_eq!(r.status, 200, "pipelined request {i}: {}", r.head);
        assert_eq!(r.header("Connection").as_deref(), Some("keep-alive"), "{}", r.head);
        assert!((r.score0() - 0.6).abs() < 1e-12);
    }
    let r = read_resp(&mut s);
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("ok").and_then(Json::as_bool), Some(true));

    // The connection is still usable for a fourth, separate request.
    send(&mut s, "GET", "/v1/models", "");
    let r = read_resp(&mut s);
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("models").unwrap().as_array().unwrap().len(), 1);

    handle.shutdown();
    srv.join().unwrap();
}

// ---------------------------------------------------------------------------
// Multi-model registry routing
// ---------------------------------------------------------------------------

#[test]
fn registry_routes_requests_by_model_name() {
    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(1)
        .register_model("nytimes", model_with_weight("corpus-a", 0.25))
        .register_model("pubmed", model_with_weight("corpus-b", 4.0))
        .default_model("pubmed")
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);

    let r = req(addr, "GET", "/v1/models", "");
    assert_eq!(r.status, 200);
    let models = r.json().get("models").unwrap().as_array().unwrap().to_vec();
    assert_eq!(models.len(), 2);
    assert_eq!(models[0].get("name").unwrap().as_str(), Some("nytimes"));
    assert_eq!(models[0].get("default").unwrap().as_bool(), Some(false));
    assert_eq!(models[1].get("name").unwrap().as_str(), Some("pubmed"));
    assert_eq!(models[1].get("default").unwrap().as_bool(), Some(true));

    let body = r#"{"words": [[3, 1]]}"#;
    let r = req(addr, "POST", "/v1/models/nytimes/score", body);
    assert!((r.score0() - 0.25).abs() < 1e-12, "nytimes slot answered");
    let r = req(addr, "POST", "/v1/models/pubmed/score", body);
    assert!((r.score0() - 4.0).abs() < 1e-12, "pubmed slot answered");
    // the legacy shim hits the *default* model, not the first-registered
    let r = req(addr, "POST", "/score", body);
    assert!((r.score0() - 4.0).abs() < 1e-12, "legacy /score routes to default");
    // per-name topics come from the right artifact
    let r = req(addr, "GET", "/v1/models/nytimes/topics", "");
    assert_eq!(r.status, 200);

    // unknown model: structured 404 naming what is registered
    let r = req(addr, "POST", "/v1/models/nope/score", body);
    assert_eq!(r.status, 404);
    let names: Vec<String> = r
        .json()
        .get("models")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|m| m.as_str().unwrap().to_string())
        .collect();
    assert_eq!(names, vec!["nytimes".to_string(), "pubmed".to_string()]);

    handle.shutdown();
    srv.join().unwrap();
}

// ---------------------------------------------------------------------------
// Hot reload under sustained concurrent load
// ---------------------------------------------------------------------------

#[test]
fn hot_reload_swaps_under_load_without_dropping_requests() {
    let _g = faultinject::test_guard(); // the watcher reads tag "model"
    let path = tmp("reload.lspm");
    model_with_weight("reload-v1", 0.5).save(&path).unwrap();

    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(2)
        .reload_poll_ms(10)
        .register("default", &path)
        .default_model("default")
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);

    let stop = Arc::new(AtomicBool::new(false));
    let errors_5xx = Arc::new(AtomicU64::new(0));
    let saw_v2 = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for _ in 0..3 {
        let (stop, errors_5xx, saw_v2) = (stop.clone(), errors_5xx.clone(), saw_v2.clone());
        clients.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let body = r#"{"words": [[3, 1]]}"#;
            while !stop.load(Ordering::Relaxed) {
                send(&mut s, "POST", "/v1/models/default/score", body);
                let r = read_resp(&mut s);
                if r.status >= 500 {
                    errors_5xx.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                assert_eq!(r.status, 200, "{}", r.head);
                let score = r.score0();
                if (score - 2.5).abs() < 1e-12 {
                    saw_v2.store(true, Ordering::Relaxed);
                } else {
                    // before the swap every answer is v1's; never garbage
                    assert!((score - 0.5).abs() < 1e-12, "unexpected score {score}");
                }
            }
        }));
    }

    // Let the hammering get going, then rewrite the artifact under it.
    // The v2 model has a different corpus name (and byte length), so the
    // watcher's (len, mtime) signature is guaranteed to change.
    std::thread::sleep(Duration::from_millis(50));
    model_with_weight("reload-v2-renamed", 2.5).save(&path).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while !saw_v2.load(Ordering::Relaxed) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    stop.store(true, Ordering::Relaxed);
    for c in clients {
        c.join().unwrap();
    }
    assert!(saw_v2.load(Ordering::Relaxed), "hot reload was never observed");
    assert_eq!(errors_5xx.load(Ordering::Relaxed), 0, "5xx during hot reload");

    // /metrics records exactly one swap (the rewrite), zero errors.
    let r = req(addr, "GET", "/v1/metrics", "");
    let text = String::from_utf8(r.body).unwrap();
    assert!(text.contains("lsspca_reloads_total 1"), "{text}");
    assert!(text.contains("lsspca_reload_errors_total 0"), "{text}");
    assert!(text.contains("lsspca_model_reloads_total{model=\"default\"} 1"), "{text}");

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Overload shedding
// ---------------------------------------------------------------------------

#[test]
fn overload_sheds_503_with_retry_after() {
    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(1)
        .max_conns(1)
        .model(model_with_weight("shed", 1.0))
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);

    // Occupy the single connection slot with a live keep-alive client.
    let mut first = TcpStream::connect(addr).unwrap();
    send(&mut first, "GET", "/v1/healthz", "");
    assert_eq!(read_resp(&mut first).status, 200);

    // The next connection must be shed at accept time: 503 + Retry-After.
    let mut second = TcpStream::connect(addr).unwrap();
    let r = read_resp(&mut second);
    assert_eq!(r.status, 503, "{}", r.head);
    assert_eq!(r.header("Retry-After").as_deref(), Some("1"), "{}", r.head);
    assert!(r.json().get("error").is_some());
    drop(second);
    drop(first);

    // Capacity returns once the held connection closes (the worker has
    // to notice the EOF, so retry until then).
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut recovered = false;
    while Instant::now() < deadline {
        if req(addr, "GET", "/v1/healthz", "").status == 200 {
            recovered = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(recovered, "server never recovered after shed");

    let r = req(addr, "GET", "/v1/metrics", "");
    let sheds: u64 = String::from_utf8(r.body)
        .unwrap()
        .lines()
        .find_map(|l| l.strip_prefix("lsspca_sheds_total ").map(|v| v.parse().unwrap()))
        .unwrap();
    assert!(sheds >= 1, "shed not counted");

    handle.shutdown();
    srv.join().unwrap();
}

// ---------------------------------------------------------------------------
// Legacy shims vs /v1: bitwise parity over the wire
// ---------------------------------------------------------------------------

#[test]
fn legacy_shims_match_v1_bodies_bitwise_over_the_wire() {
    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(1)
        .model(model_with_weight("parity", 0.6))
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);

    let doc = r#"{"words": [[3, 2], [15, 1]], "top": 2}"#;
    for (legacy, v1, method, body) in [
        ("/healthz", "/v1/healthz", "GET", ""),
        ("/topics", "/v1/models/default/topics", "GET", ""),
        ("/score", "/v1/models/default/score", "POST", doc),
    ] {
        let l = req(addr, method, legacy, body);
        let v = req(addr, method, v1, body);
        assert_eq!(l.status, 200, "{legacy}");
        assert_eq!(v.status, 200, "{v1}");
        assert_eq!(l.body, v.body, "{legacy} vs {v1}: bodies must be byte-identical");
        assert_eq!(l.header("Deprecation").as_deref(), Some("true"), "{legacy}");
        assert!(l.header("Link").unwrap().contains(v1), "{legacy} Link points at {v1}");
        assert_eq!(v.header("Deprecation"), None, "{v1} is not deprecated");
    }

    handle.shutdown();
    srv.join().unwrap();
}

// ---------------------------------------------------------------------------
// Malformed / oversized requests
// ---------------------------------------------------------------------------

#[test]
fn malformed_and_oversized_requests_get_structured_errors() {
    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(1)
        .max_body_bytes(256)
        .model(model_with_weight("fuzz", 1.0))
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);

    // Parse failures: 400/501/413, each with a JSON error body, and the
    // connection closes afterwards (framing is unknown past a bad head).
    for (bytes, want) in [
        (b"nonsense\r\n\r\n".to_vec(), 400),
        (b"GET /v1/models HTTP/1.1\r\nno-colon-here\r\n\r\n".to_vec(), 400),
        (b"POST /score HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec(), 400),
        (b"POST /score HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(), 501),
        (b"POST /score HTTP/1.1\r\nContent-Length: 99999\r\n\r\n".to_vec(), 413),
    ] {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&bytes).unwrap();
        let r = read_resp(&mut s);
        assert_eq!(r.status, want, "{:?} -> {}", String::from_utf8_lossy(&bytes), r.head);
        assert!(r.json().get("error").is_some(), "{}", r.head);
        assert_eq!(r.header("Connection").as_deref(), Some("close"));
        let mut rest = Vec::new();
        s.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "server must close after a parse error");
    }

    // A head that never terminates is cut off at the 16 KiB budget: 431.
    let mut huge = b"GET /v1/models HTTP/1.1\r\nX-Filler: ".to_vec();
    huge.extend(vec![b'a'; 20 * 1024]);
    let r = raw(addr, &huge);
    assert_eq!(r.status, 431, "{}", r.head);

    // The old missing-Allow bug: every 405 names the allowed method.
    let r = req(addr, "GET", "/score", "");
    assert_eq!(r.status, 405);
    assert_eq!(r.header("Allow").as_deref(), Some("POST"), "{}", r.head);
    let r = req(addr, "POST", "/topics", "");
    assert_eq!(r.status, 405);
    assert_eq!(r.header("Allow").as_deref(), Some("GET"));

    // Unknown /v1 path: structured 404 listing the route table.
    let r = req(addr, "GET", "/v1/frobnicate", "");
    assert_eq!(r.status, 404);
    let routes = r.json().get("routes").unwrap().as_array().unwrap().len();
    assert_eq!(routes, 5, "404 lists the full v1 route table");

    // Valid framing with invalid JSON is a 400 that keeps the connection.
    let r = req(addr, "POST", "/v1/models/default/score", "this is not json");
    assert_eq!(r.status, 400);
    assert!(r.json().get("error").is_some());

    handle.shutdown();
    srv.join().unwrap();
}

// ---------------------------------------------------------------------------
// Reload watcher under fault injection
// ---------------------------------------------------------------------------

#[test]
fn reload_watcher_survives_injected_and_real_artifact_faults() {
    let _g = faultinject::test_guard();
    let path = tmp("faulty.lspm");
    model_with_weight("fault-v1", 0.5).save(&path).unwrap();

    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(1)
        .reload_poll_ms(10)
        .register("default", &path)
        .default_model("default")
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);
    let body = r#"{"words": [[3, 1]]}"#;
    let score_now = || req(addr, "POST", "/v1/models/default/score", body).score0();
    let wait_for_score = |want: f64| {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if (score_now() - want).abs() < 1e-12 {
                return;
            }
            assert!(Instant::now() < deadline, "never started serving score {want}");
            std::thread::sleep(Duration::from_millis(10));
        }
    };

    // 1. A transient injected read interrupt: the watcher's retrying
    //    reader absorbs it and the swap still lands.
    faultinject::scoped(FaultPlan::parse("rinterrupt:model@4").unwrap(), || {
        model_with_weight("fault-v2-renamed", 2.5).save(&path).unwrap();
        wait_for_score(2.5);
    });

    // 2. A truncated (checksum-invalid) artifact: the reload fails, the
    //    error is counted, and the previous model keeps serving.
    let good = std::fs::read(&path).unwrap();
    std::fs::write(&path, &good[..good.len() / 2]).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = req(addr, "GET", "/v1/metrics", "");
        let errs: u64 = String::from_utf8(r.body)
            .unwrap()
            .lines()
            .find_map(|l| l.strip_prefix("lsspca_reload_errors_total ").map(|v| v.parse().unwrap()))
            .unwrap();
        if errs >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "reload error never counted");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!((score_now() - 2.5).abs() < 1e-12, "previous model must keep serving");

    // 3. A good artifact heals it: the next poll swaps.
    model_with_weight("fault-v3-renamed-again", 7.25).save(&path).unwrap();
    wait_for_score(7.25);

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_file(&path).ok();
}
