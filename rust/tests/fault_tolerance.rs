//! Fault tolerance: kill-and-resume job state, dead-letter quarantine,
//! transient-I/O retry classification, and the deterministic
//! fault-injection harness — exercised both in-process (library API)
//! and through the `lsspca` binary (the `LSSPCA_FAULTS` env path).
//!
//! Artifacts (dead-letter queues, cache dirs with job state) are created
//! under `LSSPCA_FAULT_DIR` when set, so CI can upload the leftovers of
//! a failing test; on success each test removes its own directory.

use std::path::PathBuf;
use std::process::Command;

use lsspca::config::PipelineConfig;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::error::LsspcaError;
use lsspca::jobstate::{self, JobState, KIND_VARIANCE};
use lsspca::moments::FeatureMoments;
use lsspca::session::Session;
use lsspca::stream::{resumable_variance_pass, StreamOptions, SynthSource};
use lsspca::util::{faultinject, retry};

/// Root for test artifacts: `LSSPCA_FAULT_DIR` (CI upload point) or the
/// system temp dir.
fn artifact_root() -> PathBuf {
    match std::env::var("LSSPCA_FAULT_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir(),
    }
}

fn tmp(name: &str) -> PathBuf {
    let p = artifact_root().join(format!("lsspca_ft_{}_{name}", std::process::id()));
    std::fs::create_dir_all(p.parent().unwrap()).ok();
    p
}

fn bin() -> PathBuf {
    // target/<profile>/lsspca next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("lsspca");
    p
}

/// Run the binary; returns (exit code, success, stdout+stderr).
fn run_cli(args: &[&str], env: &[(&str, &str)]) -> (Option<i32>, bool, String) {
    let mut cmd = Command::new(bin());
    cmd.args(args);
    for &(k, v) in env {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("spawn lsspca");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.code(), out.status.success(), text)
}

fn ft_config(cache_dir: &std::path::Path) -> PipelineConfig {
    PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 600,
        synth_vocab: 1500,
        workers: 3,
        chunk_docs: 64,
        cache_dir: cache_dir.display().to_string(),
        robust_job_state_chunks: 1,
        ..Default::default()
    }
}

/// The corpus digest `run_stream` derives for a synthetic config — same
/// identity string, same FNV fold.
fn synth_key(cfg: &PipelineConfig) -> u64 {
    let spec = CorpusSpec::preset(&cfg.synth_preset)
        .unwrap()
        .scaled(cfg.synth_docs, cfg.synth_vocab);
    let corpus = SynthCorpus::new(spec, cfg.seed);
    lsspca::checkpoint::corpus_key(&format!(
        "synth:{}:{}:{}:{}",
        corpus.spec.name, corpus.spec.num_docs, corpus.spec.vocab_size, corpus.seed
    ))
}

#[test]
fn resume_from_job_state_is_bitwise_identical() {
    let cache_a = tmp("resume_clean");
    let cache_b = tmp("resume_killed");
    std::fs::remove_dir_all(&cache_a).ok();
    std::fs::remove_dir_all(&cache_b).ok();

    // Reference: one uninterrupted run.
    let cfg_a = ft_config(&cache_a);
    let mut sess = Session::from_config(cfg_a.clone()).unwrap();
    let stats_a = sess.stream().unwrap();
    let (var_a, mean_a, docs_a) = (
        stats_a.variances.variance.clone(),
        stats_a.variances.mean.clone(),
        stats_a.docs,
    );
    let key = synth_key(&cfg_a);
    let ckpt_a = std::fs::read(lsspca::checkpoint::path_for(&cache_a, key)).unwrap();

    // "Killed" run: drive the resumable pass directly, persisting job
    // state every chunk, and die (persist error) after the 3rd snapshot —
    // the moment-in-time a SIGKILL would leave behind.
    let cfg_b = ft_config(&cache_b);
    let spec = CorpusSpec::preset("nytimes").unwrap().scaled(600, 1500);
    let corpus = SynthCorpus::new(spec, cfg_b.seed);
    let js_path = jobstate::path_for(&cache_b, key);
    let opts = StreamOptions {
        workers: cfg_b.workers,
        chunk_docs: cfg_b.chunk_docs,
        queue_depth: cfg_b.queue_depth,
    };
    let mut saves = 0u64;
    let chunk_docs = cfg_b.chunk_docs as u64;
    let res = resumable_variance_pass(
        &mut SynthSource::new(&corpus),
        opts,
        None,
        1,
        |m, done| {
            jobstate::save(
                &js_path,
                &JobState {
                    key,
                    kind: KIND_VARIANCE,
                    chunk_docs,
                    completed_chunks: done,
                    moments: m.clone(),
                },
            )?;
            saves += 1;
            if saves == 3 {
                return Err(LsspcaError::io("simulated kill"));
            }
            Ok(())
        },
    );
    let err = res.unwrap_err().to_string();
    assert!(err.contains("simulated kill"), "persist failure must be the root cause: {err}");
    let js = jobstate::load(&js_path, key, 1500, chunk_docs).unwrap().unwrap();
    assert_eq!(js.completed_chunks, 3, "job state snapshots the last completed chunk");

    // Restart: the session finds the job state, resumes at chunk 3, and
    // the final statistics are bitwise those of the uninterrupted run.
    let mut sess_b = Session::from_config(cfg_b).unwrap();
    let got = sess_b.stream().unwrap();
    assert_eq!(got.docs, docs_a);
    assert_eq!(got.variances.variance.len(), var_a.len());
    for (a, b) in var_a.iter().zip(&got.variances.variance) {
        assert_eq!(a.to_bits(), b.to_bits(), "resumed variances must be bitwise identical");
    }
    for (a, b) in mean_a.iter().zip(&got.variances.mean) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert!(!js_path.exists(), "job state is removed once the pass completes");
    let ckpt_b = std::fs::read(lsspca::checkpoint::path_for(&cache_b, key)).unwrap();
    assert_eq!(ckpt_a, ckpt_b, "checkpoint written after resume must match byte for byte");

    std::fs::remove_dir_all(&cache_a).ok();
    std::fs::remove_dir_all(&cache_b).ok();
}

#[test]
fn stale_or_foreign_job_state_is_rejected_not_resumed() {
    let cache_ref = tmp("stale_ref");
    let cache_foreign = tmp("stale_foreign");
    let cache_chunks = tmp("stale_chunks");
    for d in [&cache_ref, &cache_foreign, &cache_chunks] {
        std::fs::remove_dir_all(d).ok();
    }
    let cfg = ft_config(&cache_ref);
    let key = synth_key(&cfg);
    let mut sess = Session::from_config(cfg.clone()).unwrap();
    let var_ref = sess.stream().unwrap().variances.variance.clone();

    // A job state from a *different corpus* sitting at this corpus' path
    // (e.g. a digest collision after a cache-dir copy) must be ignored.
    let foreign = JobState {
        key: key ^ 0xdead_beef,
        kind: KIND_VARIANCE,
        chunk_docs: cfg.chunk_docs as u64,
        completed_chunks: 4,
        moments: FeatureMoments::new(1500),
    };
    jobstate::save(&jobstate::path_for(&cache_foreign, key), &foreign).unwrap();
    let mut cfg_f = cfg.clone();
    cfg_f.cache_dir = cache_foreign.display().to_string();
    let mut sess_f = Session::from_config(cfg_f).unwrap();
    let got = sess_f.stream().unwrap();
    for (a, b) in var_ref.iter().zip(&got.variances.variance) {
        assert_eq!(a.to_bits(), b.to_bits(), "rejected job state must not affect the result");
    }

    // A job state recorded at a different chunk size is stale: chunk
    // boundaries would move, so the pass starts over.
    let stale = JobState {
        key,
        kind: KIND_VARIANCE,
        chunk_docs: 999,
        completed_chunks: 2,
        moments: FeatureMoments::new(1500),
    };
    jobstate::save(&jobstate::path_for(&cache_chunks, key), &stale).unwrap();
    let mut cfg_c = cfg.clone();
    cfg_c.cache_dir = cache_chunks.display().to_string();
    let mut sess_c = Session::from_config(cfg_c).unwrap();
    let got = sess_c.stream().unwrap();
    for (a, b) in var_ref.iter().zip(&got.variances.variance) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    for d in [&cache_ref, &cache_foreign, &cache_chunks] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn torn_write_never_corrupts_persisted_job_state() {
    let _g = faultinject::test_guard();
    let dir = tmp("torn");
    std::fs::remove_dir_all(&dir).ok();
    let path = jobstate::path_for(&dir, 0xfeed);
    let snap = |completed: u64| JobState {
        key: 0xfeed,
        kind: KIND_VARIANCE,
        chunk_docs: 64,
        completed_chunks: completed,
        moments: FeatureMoments::new(8),
    };
    jobstate::save(&path, &snap(1)).unwrap();
    let good = std::fs::read(&path).unwrap();

    // A power cut mid-write of the *next* snapshot: the torn bytes land
    // in the tmp file only; the published snapshot must stay intact.
    faultinject::scoped(faultinject::FaultPlan::parse("wtorn:jobstate@8").unwrap(), || {
        let e = jobstate::save(&path, &snap(2)).unwrap_err();
        assert!(e.to_string().contains("torn"), "{e}");
        assert!(!e.is_transient(), "a torn write is damage, not weather");
    });
    assert_eq!(std::fs::read(&path).unwrap(), good, "published snapshot survived the tear");
    let js = jobstate::load(&path, 0xfeed, 8, 64).unwrap().unwrap();
    assert_eq!(js.completed_chunks, 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn retry_exhaustion_maps_to_transient_cache_error() {
    let fast = retry::RetryPolicy { attempts: 3, base_delay_ms: 0, max_delay_ms: 0 };
    let mut calls = 0;
    let err = retry::with_retry(&fast, || -> std::io::Result<()> {
        calls += 1;
        Err(std::io::Error::new(std::io::ErrorKind::TimedOut, "nfs mount wobble"))
    })
    .unwrap_err();
    assert_eq!(calls, 3, "transient failures burn the whole budget");
    assert!(err.transient);
    // The mapping the cache layers (checkpoint/jobstate/shardcache) use:
    // exhausted-transient → Cache { transient: true } → exit code 4.
    let mapped = LsspcaError::cache_transient(err.describe("job state write"));
    assert!(mapped.is_transient());
    assert_eq!(mapped.exit_code(), 4);
    assert!(mapped.to_string().contains("after 3 attempts"), "{mapped}");

    // Permanent failures surface immediately and are not transient.
    let mut calls = 0;
    let err = retry::with_retry(&fast, || -> std::io::Result<()> {
        calls += 1;
        Err(std::io::Error::new(std::io::ErrorKind::PermissionDenied, "denied"))
    })
    .unwrap_err();
    assert_eq!(calls, 1);
    assert!(!err.transient);
    assert!(!LsspcaError::cache(err.describe("checkpoint write")).is_transient());
}

#[test]
fn cli_kill_mid_pass_then_rerun_matches_clean_run() {
    let root = tmp("cli_kill");
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let corpus = root.join("corpus.txt.gz");
    let corpus_s = corpus.display().to_string();
    let (_, ok, text) = run_cli(
        &["gen", "--out", &corpus_s, "--preset", "nytimes", "--docs", "400", "--vocab", "1500"],
        &[],
    );
    assert!(ok, "{text}");
    // chunk_docs is a config-file knob; persist job state every chunk so
    // the scripted kill lands inside the pass.
    let cfg = root.join("ft.toml");
    std::fs::write(&cfg, "[stream]\nchunk_docs = 32\n\n[robustness]\njob_state_chunks = 1\n")
        .unwrap();
    let cfg_s = cfg.display().to_string();
    let killed_cache = root.join("cache_killed");
    let clean_cache = root.join("cache_clean");
    let killed_s = killed_cache.display().to_string();
    let clean_s = clean_cache.display().to_string();
    let args: Vec<&str> = vec![
        "run", "--config", &cfg_s, "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32",
        "--cache-dir", &killed_s,
    ];
    let args_clean: Vec<&str> = vec![
        "run", "--config", &cfg_s, "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32",
        "--cache-dir", &clean_s,
    ];

    // Run 1: abort the process mid-write of the first job-state snapshot.
    let (_, ok, _) = run_cli(&args, &[("LSSPCA_FAULTS", "wkill:jobstate@8")]);
    assert!(!ok, "the scripted kill must abort the run");
    let lspv = |dir: &std::path::Path| {
        std::fs::read_dir(dir)
            .ok()
            .into_iter()
            .flatten()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| p.extension().is_some_and(|x| x == "lspv"))
    };
    assert!(lspv(&killed_cache).is_none(), "no checkpoint may exist after the kill");

    // Run 2: no faults — recovers (the torn tmp snapshot is invisible;
    // the atomic write never published it) and completes.
    let (_, ok, text) = run_cli(&args, &[]);
    assert!(ok, "{text}");

    // Reference: a never-killed run in a fresh cache. The final variance
    // checkpoints must agree byte for byte.
    let (_, ok, text) = run_cli(&args_clean, &[]);
    assert!(ok, "{text}");
    let a = std::fs::read(lspv(&killed_cache).expect("checkpoint after recovery")).unwrap();
    let b = std::fs::read(lspv(&clean_cache).expect("checkpoint of clean run")).unwrap();
    assert_eq!(a, b, "post-crash rerun must produce a bitwise-identical checkpoint");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn cli_dead_letter_quarantine_budget_and_dlq_command() {
    let root = tmp("cli_dlq");
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let corpus = root.join("corpus.txt");
    let corpus_s = corpus.display().to_string();
    let (_, ok, text) = run_cli(
        &["gen", "--out", &corpus_s, "--preset", "nytimes", "--docs", "300", "--vocab", "1200"],
        &[],
    );
    assert!(ok, "{text}");
    // Splice three malformed records at the top of the data section:
    // zero doc id, out-of-range word id, non-numeric count.
    let txt = std::fs::read_to_string(&corpus).unwrap();
    let mut lines: Vec<&str> = txt.lines().collect();
    lines.splice(3..3, ["0 5 1", "1 999999 2", "1 7 x"]);
    std::fs::write(&corpus, lines.join("\n") + "\n").unwrap();

    // Strict mode (the default): the first malformed record aborts with
    // the corpus exit code.
    let (code, ok, text) =
        run_cli(&["run", "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32"], &[]);
    assert!(!ok);
    assert_eq!(code, Some(6), "{text}");

    // With a budget the run completes and the records are quarantined.
    let dlq = root.join("dlq.jsonl");
    let dlq_s = dlq.display().to_string();
    let (_, ok, text) = run_cli(
        &[
            "run", "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32",
            "--max-bad-records", "10", "--dead-letter-path", &dlq_s,
        ],
        &[],
    );
    assert!(ok, "{text}");
    assert!(text.contains("quarantined"), "{text}");
    assert!(dlq.exists());

    // `lsspca dlq` inspects the queue: count, per-reason histogram, crc.
    let (_, ok, text) = run_cli(&["dlq", "--path", &dlq_s], &[]);
    assert!(ok, "{text}");
    assert!(text.contains("3 quarantined records"), "{text}");
    for reason in ["zero-id", "word-out-of-range", "bad-count"] {
        assert!(text.contains(reason), "missing {reason}:\n{text}");
    }
    assert!(!text.contains("WARNING"), "all records must pass their crc:\n{text}");

    // `dlq --retry`: none of these records can be salvaged, and the
    // command says so with the corpus exit code.
    let (code, ok, text) =
        run_cli(&["dlq", "--path", &dlq_s, "--retry", "--vocab-size", "1200"], &[]);
    assert!(!ok);
    assert_eq!(code, Some(6), "{text}");
    assert!(text.contains("0 recoverable / 3 permanently malformed"), "{text}");

    // A budget below the damage aborts with the corpus exit code and
    // points at the queue.
    let dlq2 = root.join("dlq2.jsonl");
    let dlq2_s = dlq2.display().to_string();
    let (code, ok, text) = run_cli(
        &[
            "run", "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32",
            "--max-bad-records", "2", "--dead-letter-path", &dlq2_s,
        ],
        &[],
    );
    assert!(!ok);
    assert_eq!(code, Some(6), "{text}");
    assert!(text.contains("too many bad records"), "{text}");
    std::fs::remove_dir_all(&root).ok();
}
