//! Equivalence guarantees for the PR's performance machinery:
//!
//! (a) the warm-started / active-set hot path (QP `solve_masked_warm`,
//!     BCA `SolverWorkspace`) reaches the same optimum as the cold-start
//!     reference path — φ within 1e-6, matching KKT residuals, iterates
//!     staying PD/symmetric;
//! (b) the parallel kernels (λ-search probes, path grids, Gram /
//!     covariance shards, deflation row blocks) produce results identical
//!     at `threads = 1` and `threads = 4` — the work decomposition is
//!     fixed by the inputs, never by the thread count.

use lsspca::corpus::models::spiked_covariance_with_u;
use lsspca::data::SymMat;
use lsspca::solver::bca::{self, BcaOptions, SolverWorkspace};
use lsspca::solver::lambda::{search, LambdaSearchOptions};
use lsspca::solver::path::{compute, PathOptions};
use lsspca::solver::qp::{self, QpOptions};
use lsspca::util::check::{close, ensure, property};
use lsspca::util::rng::Rng;

// ---------------------------------------------------------------------------
// (a) warm-start / active-set ≡ cold-start reference
// ---------------------------------------------------------------------------

#[test]
fn prop_warm_qp_matches_cold_reference() {
    property("warm/active-set QP == cold QP (R², KKT)", 30, |rng| {
        let n = rng.range(2, 24);
        let y = SymMat::random_psd(n, n + 3, 0.02, rng);
        let s: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let lambda = rng.range_f64(0.05, 1.0);
        let radius = vec![lambda; n];
        let opts = QpOptions::default();
        let (mut u, mut w) = (Vec::new(), Vec::new());
        let cold = qp::solve_masked(&y, &s, &radius, None, opts, &mut u, &mut w);
        // Warm start from a random feasible-ish point (gets clamped), and
        // from the cold solution itself (one verification sweep).
        for warm_kind in 0..2 {
            let seed: Vec<f64> = if warm_kind == 0 {
                (0..n).map(|i| s[i] + rng.range_f64(-2.0, 2.0)).collect()
            } else {
                cold.u.clone()
            };
            let (mut u2, mut w2, mut active) = (Vec::new(), Vec::new(), Vec::new());
            let warm = qp::solve_masked_warm(
                &y, &s, &radius, None, opts, Some(&seed), &mut u2, &mut w2, &mut active,
            );
            close(warm.r_squared, cold.r_squared, 1e-6)
                .map_err(|e| format!("R² mismatch (kind {warm_kind}): {e}"))?;
            let res = qp::kkt_residual(&y, &s, lambda, &u2);
            ensure(
                res < 1e-6 * (1.0 + y.trace()),
                format!("warm KKT residual {res} (kind {warm_kind})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_warm_qp_respects_skip_and_pins() {
    property("warm QP honors skip + zero radius", 20, |rng| {
        let n = rng.range(3, 16);
        let y = SymMat::random_psd(n, n + 2, 0.05, rng);
        let lambda = rng.range_f64(0.1, 0.8);
        let j = rng.below(n);
        let mut center: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        center[j] = 0.0;
        let mut radius = vec![lambda; n];
        radius[j] = 0.0;
        let pin = rng.below(n);
        radius[pin] = 0.0;
        let seed: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let (mut u, mut w, mut active) = (Vec::new(), Vec::new(), Vec::new());
        let warm = qp::solve_masked_warm(
            &y,
            &center,
            &radius,
            Some(j),
            QpOptions::default(),
            Some(&seed),
            &mut u,
            &mut w,
            &mut active,
        );
        ensure(u[j] == 0.0, "skip coordinate must stay 0")?;
        ensure(u[pin] == center[pin], "pinned coordinate must sit at center")?;
        let (mut u2, mut w2) = (Vec::new(), Vec::new());
        let cold = qp::solve_masked(
            &y, &center, &radius, Some(j), QpOptions::default(), &mut u2, &mut w2,
        );
        close(warm.r_squared, cold.r_squared, 1e-6)?;
        Ok(())
    });
}

#[test]
fn prop_workspace_bca_matches_reference() {
    // The barrier problem (6) is strictly concave — its maximizer is
    // unique — so whenever BOTH paths converge (outer early-exit fired)
    // they must land on the same φ. On near-degenerate instances the two
    // *trajectories* legitimately differ mid-flight (degenerate column
    // QPs have multiple optimal u with equal R²), which is why the gate
    // is convergence, not sweep count.
    property("workspace BCA solve == reference solve (φ, PD, symmetric)", 10, |rng| {
        let n = rng.range(3, 14);
        let sigma = SymMat::random_psd(n, 2 * n, 0.1, rng);
        let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
        let lambda = rng.range_f64(0.1, 0.8) * min_diag;
        // Generous budgets so each inner QP fully converges on both paths.
        let opts = BcaOptions {
            max_sweeps: 120,
            tol: 1e-7,
            qp: QpOptions { max_sweeps: 300, tol: 1e-11 },
            ..Default::default()
        };
        let hot = bca::solve(&sigma, lambda, &opts);
        let cold = bca::solve_reference(&sigma, lambda, &opts);
        ensure(hot.x.asymmetry() < 1e-9, "workspace iterate must stay symmetric")?;
        ensure(
            lsspca::linalg::chol::is_psd(&hot.x, 1e-10),
            "workspace iterate must stay PSD",
        )?;
        ensure(hot.phi.is_finite(), "φ must be finite")?;
        if hot.sweeps < opts.max_sweeps && cold.sweeps < opts.max_sweeps {
            close(hot.phi, cold.phi, 1e-6).map_err(|e| format!("φ diverged: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_workspace_sweeps_keep_barrier_monotone() {
    // Every warm-started column update still exactly maximizes the
    // barrier objective over its row/column block, so the objective can
    // never decrease (slack covers log-det evaluation noise once X gets
    // concentrated).
    property("workspace sweeps never decrease the barrier objective", 8, |rng| {
        let n = rng.range(3, 12);
        let sigma = SymMat::random_psd(n, 2 * n, 0.15, rng);
        let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
        let lambda = rng.range_f64(0.1, 0.7) * min_diag;
        let opts = BcaOptions {
            qp: QpOptions { max_sweeps: 300, tol: 1e-11 },
            ..Default::default()
        };
        let beta = opts.epsilon / n as f64;
        let mut x = SymMat::identity(n);
        let mut ws = SolverWorkspace::new(n);
        let mut prev = bca::barrier_objective(&x, &sigma, lambda, beta).ok_or("X0 not PD")?;
        for sweep_no in 0..4 {
            bca::sweep_ws(&mut x, &sigma, lambda, beta, &opts, &mut ws);
            let cur = bca::barrier_objective(&x, &sigma, lambda, beta)
                .ok_or("hot iterate left the PD cone")?;
            ensure(
                cur >= prev - 3e-5 * (1.0 + prev.abs()),
                format!("barrier dropped on sweep {sweep_no}: {prev} → {cur}"),
            )?;
            prev = cur;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// (b) parallel == serial, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn prop_lambda_search_identical_across_thread_counts() {
    property("λ-search: threads=1 == threads=4", 6, |rng| {
        let n = rng.range(10, 30);
        let (sigma, _) = spiked_covariance_with_u(n, 3 * n, 4, 4.0, rng);
        let mk = |threads: usize| LambdaSearchOptions {
            target_card: 4,
            slack: 1,
            max_evals: 8,
            probes_per_round: 3,
            threads,
            bca: BcaOptions { max_sweeps: 8, track_history: false, ..Default::default() },
            ..Default::default()
        };
        let serial = search(&sigma, &mk(1));
        let par = search(&sigma, &mk(4));
        ensure(serial.lambda == par.lambda, "chosen λ must be identical")?;
        ensure(serial.solution.phi == par.solution.phi, "φ must be identical")?;
        ensure(serial.trace.len() == par.trace.len(), "trace length must match")?;
        for (a, b) in serial.trace.iter().zip(&par.trace) {
            ensure(
                a.lambda == b.lambda && a.cardinality == b.cardinality && a.phi == b.phi,
                "trace entries must be bitwise identical",
            )?;
        }
        ensure(serial.pc.support == par.pc.support, "supports must match")?;
        Ok(())
    });
}

#[test]
fn prop_path_identical_across_thread_counts() {
    property("path grid: threads=1 == threads=4", 4, |rng| {
        let n = rng.range(8, 20);
        let sigma = SymMat::random_psd(n, 2 * n, 0.1, rng);
        let mk = |threads: usize| PathOptions { points: 7, threads, ..Default::default() };
        let serial = compute(&sigma, &mk(1));
        let par = compute(&sigma, &mk(4));
        ensure(serial.len() == par.len(), "same number of points")?;
        for (a, b) in serial.iter().zip(&par) {
            ensure(a.lambda == b.lambda, "λ grid must match")?;
            ensure(a.survivors == b.survivors, "survivors must match")?;
            ensure(a.phi == b.phi, "φ must be bitwise identical")?;
            ensure(a.pc.vector == b.pc.vector, "loadings must be bitwise identical")?;
        }
        Ok(())
    });
}

#[test]
fn prop_gram_and_covariance_identical_across_thread_counts() {
    property("gram/covariance shards: threads=1 == threads=4", 10, |rng| {
        // Gram over enough rows to span several fixed shards.
        let n = rng.range(2, 10);
        let m = rng.range(300, 900);
        let data: Vec<f64> = (0..m * n).map(|_| rng.gauss()).collect();
        let g1 = lsspca::cov::gram_parallel(m, n, &data, 1);
        let g4 = lsspca::cov::gram_parallel(m, n, &data, 4);
        ensure(g1.as_slice() == g4.as_slice(), "gram must be bitwise identical")?;
        Ok(())
    });
}

#[test]
fn covariance_from_csr_identical_across_thread_counts() {
    // Multi-shard CSR covariance (> 1024 docs) must not depend on threads.
    let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(2600, 500);
    let corpus = lsspca::corpus::SynthCorpus::new(spec, 5);
    let csr = corpus.to_csr();
    let kept: Vec<usize> = (0..40).collect();
    let c1 = lsspca::cov::covariance_from_csr_par(&csr, &kept, 1);
    let c4 = lsspca::cov::covariance_from_csr_par(&csr, &kept, 4);
    assert_eq!(c1.as_slice(), c4.as_slice(), "covariance must be bitwise identical");
}

#[test]
fn deflation_identical_across_thread_counts() {
    let mut rng = Rng::seed_from(808);
    for scheme in [
        lsspca::solver::deflate::Scheme::Projection,
        lsspca::solver::deflate::Scheme::Hotelling,
    ] {
        let base = SymMat::random_psd(130, 200, 0.1, &mut rng);
        let mut v = rng.gauss_vec(130);
        lsspca::linalg::vec::normalize(&mut v);
        let mut s1 = base.clone();
        let mut s4 = base.clone();
        scheme.apply_par(&mut s1, &v, 1);
        scheme.apply_par(&mut s4, &v, 4);
        assert_eq!(s1.as_slice(), s4.as_slice(), "{scheme:?} deflation must be identical");
    }
}

#[test]
fn moments_finalize_identical_across_thread_counts() {
    let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(300, 9000);
    let corpus = lsspca::corpus::SynthCorpus::new(spec, 12);
    let mut m = lsspca::moments::FeatureMoments::new(9000);
    for d in 0..300 {
        m.push_doc(&corpus.generate_doc(d));
    }
    let f1 = m.finalize_par(1);
    let f4 = m.finalize_par(4);
    assert_eq!(f1.variance, f4.variance);
    assert_eq!(f1.mean, f4.mean);
    assert_eq!(f1.second_moment, f4.second_moment);
}
