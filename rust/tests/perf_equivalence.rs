//! Equivalence guarantees for the PR's performance machinery:
//!
//! (a) the warm-started / active-set hot path (QP `solve_masked_warm`,
//!     BCA `SolverWorkspace`) reaches the same optimum as the cold-start
//!     reference path — φ within 1e-6, matching KKT residuals, iterates
//!     staying PD/symmetric;
//! (b) the parallel kernels (λ-search probes, path grids, Gram /
//!     covariance shards, deflation row blocks) produce results identical
//!     at `threads = 1` and `threads = 4` — the work decomposition is
//!     fixed by the inputs, never by the thread count;
//!
//! (c) the covariance-operator layer: the `DenseCov` backend and the
//!     per-λ `MaskedCov` nested-elimination views reproduce the dense
//!     pipeline **bitwise** (identical φ, loadings, supports), the
//!     implicit `GramCov` backend matches to FP-reassociation tolerance,
//!     and Thm-2.1 survivor sets nest monotonically in λ;
//!
//! (d) the SIMD kernel dispatch layer: the full pipeline (stream →
//!     eliminate → solve → topics) produces **bitwise-identical** reports
//!     under `kernels = scalar` and `kernels = auto` — the tentpole
//!     guarantee of the `lsspca::kernels` module, checked end to end.

use lsspca::corpus::models::spiked_covariance_with_u;
use lsspca::covop::{DenseCov, GramCov, MaskedCov};
use lsspca::data::SymMat;
use lsspca::elim::SafeElimination;
use lsspca::solver::bca::{self, BcaOptions, SolverWorkspace};
use lsspca::solver::lambda::{search, LambdaSearchOptions};
use lsspca::solver::path::{compute, PathOptions};
use lsspca::solver::qp::{self, QpOptions};
use lsspca::util::check::{close, ensure, property};
use lsspca::util::rng::Rng;

// ---------------------------------------------------------------------------
// (a) warm-start / active-set ≡ cold-start reference
// ---------------------------------------------------------------------------

#[test]
fn prop_warm_qp_matches_cold_reference() {
    property("warm/active-set QP == cold QP (R², KKT)", 30, |rng| {
        let n = rng.range(2, 24);
        let y = SymMat::random_psd(n, n + 3, 0.02, rng);
        let s: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let lambda = rng.range_f64(0.05, 1.0);
        let radius = vec![lambda; n];
        let opts = QpOptions::default();
        let (mut u, mut w) = (Vec::new(), Vec::new());
        let cold = qp::solve_masked(&y, &s, &radius, None, opts, &mut u, &mut w);
        // Warm start from a random feasible-ish point (gets clamped), and
        // from the cold solution itself (one verification sweep).
        for warm_kind in 0..2 {
            let seed: Vec<f64> = if warm_kind == 0 {
                (0..n).map(|i| s[i] + rng.range_f64(-2.0, 2.0)).collect()
            } else {
                cold.u.clone()
            };
            let (mut u2, mut w2, mut active) = (Vec::new(), Vec::new(), Vec::new());
            let warm = qp::solve_masked_warm(
                &y, &s, &radius, None, opts, Some(&seed), &mut u2, &mut w2, &mut active,
            );
            close(warm.r_squared, cold.r_squared, 1e-6)
                .map_err(|e| format!("R² mismatch (kind {warm_kind}): {e}"))?;
            let res = qp::kkt_residual(&y, &s, lambda, &u2);
            ensure(
                res < 1e-6 * (1.0 + y.trace()),
                format!("warm KKT residual {res} (kind {warm_kind})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_warm_qp_respects_skip_and_pins() {
    property("warm QP honors skip + zero radius", 20, |rng| {
        let n = rng.range(3, 16);
        let y = SymMat::random_psd(n, n + 2, 0.05, rng);
        let lambda = rng.range_f64(0.1, 0.8);
        let j = rng.below(n);
        let mut center: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        center[j] = 0.0;
        let mut radius = vec![lambda; n];
        radius[j] = 0.0;
        let pin = rng.below(n);
        radius[pin] = 0.0;
        let seed: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let (mut u, mut w, mut active) = (Vec::new(), Vec::new(), Vec::new());
        let warm = qp::solve_masked_warm(
            &y,
            &center,
            &radius,
            Some(j),
            QpOptions::default(),
            Some(&seed),
            &mut u,
            &mut w,
            &mut active,
        );
        ensure(u[j] == 0.0, "skip coordinate must stay 0")?;
        ensure(u[pin] == center[pin], "pinned coordinate must sit at center")?;
        let (mut u2, mut w2) = (Vec::new(), Vec::new());
        let cold = qp::solve_masked(
            &y, &center, &radius, Some(j), QpOptions::default(), &mut u2, &mut w2,
        );
        close(warm.r_squared, cold.r_squared, 1e-6)?;
        Ok(())
    });
}

#[test]
fn prop_workspace_bca_matches_reference() {
    // The barrier problem (6) is strictly concave — its maximizer is
    // unique — so whenever BOTH paths converge (outer early-exit fired)
    // they must land on the same φ. On near-degenerate instances the two
    // *trajectories* legitimately differ mid-flight (degenerate column
    // QPs have multiple optimal u with equal R²), which is why the gate
    // is convergence, not sweep count.
    property("workspace BCA solve == reference solve (φ, PD, symmetric)", 10, |rng| {
        let n = rng.range(3, 14);
        let sigma = SymMat::random_psd(n, 2 * n, 0.1, rng);
        let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
        let lambda = rng.range_f64(0.1, 0.8) * min_diag;
        // Generous budgets so each inner QP fully converges on both paths.
        let opts = BcaOptions {
            max_sweeps: 120,
            tol: 1e-7,
            qp: QpOptions { max_sweeps: 300, tol: 1e-11 },
            ..Default::default()
        };
        let hot = bca::solve(&sigma, lambda, &opts);
        let cold = bca::solve_reference(&sigma, lambda, &opts);
        ensure(hot.x.asymmetry() < 1e-9, "workspace iterate must stay symmetric")?;
        ensure(
            lsspca::linalg::chol::is_psd(&hot.x, 1e-10),
            "workspace iterate must stay PSD",
        )?;
        ensure(hot.phi.is_finite(), "φ must be finite")?;
        if hot.sweeps < opts.max_sweeps && cold.sweeps < opts.max_sweeps {
            close(hot.phi, cold.phi, 1e-6).map_err(|e| format!("φ diverged: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_workspace_sweeps_keep_barrier_monotone() {
    // Every warm-started column update still exactly maximizes the
    // barrier objective over its row/column block, so the objective can
    // never decrease (slack covers log-det evaluation noise once X gets
    // concentrated).
    property("workspace sweeps never decrease the barrier objective", 8, |rng| {
        let n = rng.range(3, 12);
        let sigma = SymMat::random_psd(n, 2 * n, 0.15, rng);
        let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
        let lambda = rng.range_f64(0.1, 0.7) * min_diag;
        let opts = BcaOptions {
            qp: QpOptions { max_sweeps: 300, tol: 1e-11 },
            ..Default::default()
        };
        let beta = opts.epsilon / n as f64;
        let mut x = SymMat::identity(n);
        let mut ws = SolverWorkspace::new(n);
        let mut prev = bca::barrier_objective(&x, &sigma, lambda, beta).ok_or("X0 not PD")?;
        for sweep_no in 0..4 {
            bca::sweep_ws(&mut x, &sigma, lambda, beta, &opts, &mut ws);
            let cur = bca::barrier_objective(&x, &sigma, lambda, beta)
                .ok_or("hot iterate left the PD cone")?;
            ensure(
                cur >= prev - 3e-5 * (1.0 + prev.abs()),
                format!("barrier dropped on sweep {sweep_no}: {prev} → {cur}"),
            )?;
            prev = cur;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// (b) parallel == serial, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn prop_lambda_search_identical_across_thread_counts() {
    property("λ-search: threads=1 == threads=4", 6, |rng| {
        let n = rng.range(10, 30);
        let (sigma, _) = spiked_covariance_with_u(n, 3 * n, 4, 4.0, rng);
        let mk = |threads: usize| LambdaSearchOptions {
            target_card: 4,
            slack: 1,
            max_evals: 8,
            probes_per_round: 3,
            threads,
            bca: BcaOptions { max_sweeps: 8, track_history: false, ..Default::default() },
            ..Default::default()
        };
        let serial = search(&sigma, &mk(1));
        let par = search(&sigma, &mk(4));
        ensure(serial.lambda == par.lambda, "chosen λ must be identical")?;
        ensure(serial.solution.phi == par.solution.phi, "φ must be identical")?;
        ensure(serial.trace.len() == par.trace.len(), "trace length must match")?;
        for (a, b) in serial.trace.iter().zip(&par.trace) {
            ensure(
                a.lambda == b.lambda && a.cardinality == b.cardinality && a.phi == b.phi,
                "trace entries must be bitwise identical",
            )?;
        }
        ensure(serial.pc.support == par.pc.support, "supports must match")?;
        Ok(())
    });
}

#[test]
fn prop_path_identical_across_thread_counts() {
    property("path grid: threads=1 == threads=4", 4, |rng| {
        let n = rng.range(8, 20);
        let sigma = SymMat::random_psd(n, 2 * n, 0.1, rng);
        let mk = |threads: usize| PathOptions { points: 7, threads, ..Default::default() };
        let serial = compute(&sigma, &mk(1));
        let par = compute(&sigma, &mk(4));
        ensure(serial.len() == par.len(), "same number of points")?;
        for (a, b) in serial.iter().zip(&par) {
            ensure(a.lambda == b.lambda, "λ grid must match")?;
            ensure(a.survivors == b.survivors, "survivors must match")?;
            ensure(a.phi == b.phi, "φ must be bitwise identical")?;
            ensure(a.pc.vector == b.pc.vector, "loadings must be bitwise identical")?;
        }
        Ok(())
    });
}

#[test]
fn prop_gram_and_covariance_identical_across_thread_counts() {
    property("gram/covariance shards: threads=1 == threads=4", 10, |rng| {
        // Gram over enough rows to span several fixed shards.
        let n = rng.range(2, 10);
        let m = rng.range(300, 900);
        let data: Vec<f64> = (0..m * n).map(|_| rng.gauss()).collect();
        let g1 = lsspca::cov::gram_parallel(m, n, &data, 1);
        let g4 = lsspca::cov::gram_parallel(m, n, &data, 4);
        ensure(g1.as_slice() == g4.as_slice(), "gram must be bitwise identical")?;
        Ok(())
    });
}

#[test]
fn covariance_from_csr_identical_across_thread_counts() {
    // Multi-shard CSR covariance (> 1024 docs) must not depend on threads.
    let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(2600, 500);
    let corpus = lsspca::corpus::SynthCorpus::new(spec, 5);
    let csr = corpus.to_csr();
    let kept: Vec<usize> = (0..40).collect();
    let c1 = lsspca::cov::covariance_from_csr_par(&csr, &kept, 1);
    let c4 = lsspca::cov::covariance_from_csr_par(&csr, &kept, 4);
    assert_eq!(c1.as_slice(), c4.as_slice(), "covariance must be bitwise identical");
}

#[test]
fn deflation_identical_across_thread_counts() {
    let mut rng = Rng::seed_from(808);
    for scheme in [
        lsspca::solver::deflate::Scheme::Projection,
        lsspca::solver::deflate::Scheme::Hotelling,
    ] {
        let base = SymMat::random_psd(130, 200, 0.1, &mut rng);
        let mut v = rng.gauss_vec(130);
        lsspca::linalg::vec::normalize(&mut v);
        let mut s1 = base.clone();
        let mut s4 = base.clone();
        scheme.apply_par(&mut s1, &v, 1);
        scheme.apply_par(&mut s4, &v, 4);
        assert_eq!(s1.as_slice(), s4.as_slice(), "{scheme:?} deflation must be identical");
    }
}

// ---------------------------------------------------------------------------
// (c) covariance-operator layer
// ---------------------------------------------------------------------------

#[test]
fn prop_dense_backend_bca_bitwise_identical() {
    // The acceptance bar for the operator refactor: running the BCA solve
    // through DenseCov must give the SAME BITS as running it on the raw
    // SymMat — φ, loadings, sweep counts, everything.
    property("BCA through DenseCov == BCA on SymMat, bitwise", 10, |rng| {
        let n = rng.range(3, 16);
        let sigma = SymMat::random_psd(n, 2 * n, 0.1, rng);
        let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
        let lambda = rng.range_f64(0.1, 0.8) * min_diag;
        let opts = BcaOptions { max_sweeps: 15, ..Default::default() };
        let direct = bca::solve(&sigma, lambda, &opts);
        let through_op = bca::solve(&DenseCov::new(sigma.clone()), lambda, &opts);
        ensure(direct.phi.to_bits() == through_op.phi.to_bits(), "φ must be bit-identical")?;
        ensure(direct.sweeps == through_op.sweeps, "sweep counts must match")?;
        ensure(direct.z.as_slice() == through_op.z.as_slice(), "Z must be bit-identical")?;
        ensure(direct.x.as_slice() == through_op.x.as_slice(), "X must be bit-identical")?;
        Ok(())
    });
}

#[test]
fn prop_masked_solve_matches_submatrix_solve_bitwise() {
    // A λ-probe's masked view over the superset operator must solve to
    // the same bits as materializing the survivor submatrix (the
    // pre-refactor behavior of the λ-search / path evals).
    property("MaskedCov solve == submatrix solve, bitwise", 10, |rng| {
        let n = rng.range(6, 18);
        let sigma = SymMat::random_psd(n, 2 * n, 0.05, rng);
        let diags: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        let sorted = {
            let mut s = diags.clone();
            s.sort_by(|a, b| b.partial_cmp(a).unwrap());
            s
        };
        // a λ that keeps a strict, non-empty subset
        let keep = rng.range(2, n - 1);
        let lambda = sorted[keep];
        let elim = SafeElimination::apply(&diags, lambda, None);
        if elim.reduced() == 0 || elim.reduced() == n {
            return Ok(()); // ties collapsed to a degenerate case
        }
        let opts = BcaOptions { max_sweeps: 12, ..Default::default() };
        let masked = MaskedCov::new(&sigma, elim.kept.clone());
        let sub = sigma.submatrix(&elim.kept);
        let a = bca::solve(&masked, lambda, &opts);
        let b = bca::solve(&sub, lambda, &opts);
        ensure(a.phi.to_bits() == b.phi.to_bits(), "masked φ must be bit-identical")?;
        ensure(a.z.as_slice() == b.z.as_slice(), "masked Z must be bit-identical")?;
        ensure(a.sweeps == b.sweeps, "sweep counts must match")?;
        Ok(())
    });
}

#[test]
fn prop_nested_elimination_monotone() {
    // Thm 2.1 survivors nest: λ₁ ≤ λ₂ ⇒ kept(λ₂) ⊆ kept(λ₁), and both
    // keep the decreasing-variance order — a λ-search probe's mask is
    // always a sub-mask of every lower probe's.
    property("SafeElimination: survivor sets nest in λ", 30, |rng| {
        let n = rng.range(1, 80);
        let v: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 5.0)).collect();
        let l1 = rng.range_f64(0.0, 5.0);
        let l2 = rng.range_f64(l1, 5.0);
        let e1 = SafeElimination::apply(&v, l1, None);
        let e2 = SafeElimination::apply(&v, l2, None);
        ensure(e2.reduced() <= e1.reduced(), "higher λ cannot keep more")?;
        for k in &e2.kept {
            ensure(e1.kept.contains(k), format!("feature {k} kept at λ₂ but not λ₁"))?;
        }
        // identical variance ranking ⇒ kept(λ₂) is a prefix of kept(λ₁)
        // whenever variances are distinct (random f64s: a.s. distinct)
        ensure(e1.kept[..e2.reduced()] == e2.kept[..], "nested set must be a prefix")?;
        Ok(())
    });
}

#[test]
fn prop_lambda_search_identical_with_gram_backend() {
    // Full λ-search cross-backend: dense and implicit-Gram operators over
    // the SAME sparse corpus must choose the same support (φ to FP
    // tolerance — entry sums associate differently).
    property("λ-search: DenseCov vs GramCov agree", 5, |rng| {
        let docs = rng.range(150, 300);
        let vocab = rng.range(30, 60);
        let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(docs, vocab);
        let corpus = lsspca::corpus::SynthCorpus::new(spec, rng.below(1 << 30) as u64);
        let csr = corpus.to_csr();
        let kept: Vec<usize> = (0..vocab).collect();
        let dense = DenseCov::new(lsspca::cov::covariance_from_csr(&csr, &kept));
        let gram = GramCov::new(csr, docs as u64, 2);
        let opts = LambdaSearchOptions {
            target_card: 5,
            slack: 1,
            max_evals: 8,
            bca: BcaOptions { max_sweeps: 8, track_history: false, ..Default::default() },
            ..Default::default()
        };
        let a = search(&dense, &opts);
        let b = search(&gram, &opts);
        let mut sa = a.pc.support.clone();
        let mut sb = b.pc.support.clone();
        sa.sort_unstable();
        sb.sort_unstable();
        ensure(sa == sb, format!("supports diverged: {sa:?} vs {sb:?}"))?;
        close(a.solution.phi, b.solution.phi, 1e-7)?;
        Ok(())
    });
}

#[test]
fn gram_backend_never_materializes_dense() {
    // Smoke-check the memory contract: a full λ-search plus deflated
    // re-solves on GramCov touch Σ only through gathered rows — the
    // operator has no n̂ × n̂ buffer to begin with, and the row cache
    // stays within its configured budget.
    let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(400, 64);
    let corpus = lsspca::corpus::SynthCorpus::new(spec, 9);
    let csr = corpus.to_csr();
    let gram = GramCov::new(csr, 400, 1); // 1 MiB → ≥ 2048 rows at n̂=64
    let mut defl = lsspca::solver::deflate::DeflatedCov::new(&gram);
    let opts = LambdaSearchOptions {
        target_card: 5,
        slack: 2,
        max_evals: 6,
        bca: BcaOptions { max_sweeps: 6, track_history: false, ..Default::default() },
        ..Default::default()
    };
    for _ in 0..3 {
        let res = search(&defl, &opts);
        assert!(res.pc.cardinality() >= 1);
        defl.push(lsspca::solver::deflate::Scheme::Projection, &res.pc.vector);
    }
    let (hits, misses) = gram.cache_stats();
    assert!(hits + misses > 0, "the search must have gathered rows");
    assert!(hits > 0, "repeat gathers must hit the cache");
}

// ---------------------------------------------------------------------------
// (d) kernel dispatch tiers: scalar == auto, bit for bit, end to end
// ---------------------------------------------------------------------------

#[test]
fn pipeline_bitwise_identical_across_kernel_tiers() {
    use lsspca::config::PipelineConfig;
    use lsspca::coordinator::Pipeline;
    use lsspca::kernels::{self, KernelMode};

    // Small synthetic corpus, but the full pipeline: streamed moments,
    // Thm-2.1 elimination, reduced covariance, λ-search, BCA, deflation.
    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 400,
        synth_vocab: 1500,
        workers: 2,
        chunk_docs: 128,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 48,
        bca_sweeps: 4,
        ..Default::default()
    };
    // Tier forcing is process-global, but switches are bitwise-invisible
    // to any concurrently running test (that's the invariant under test),
    // and fast_math stays off throughout.
    kernels::force(KernelMode::Scalar).unwrap();
    let a = Pipeline::new(cfg.clone()).run().expect("scalar-tier run");
    kernels::force(KernelMode::Auto).unwrap();
    let b = Pipeline::new(cfg).run().expect("auto-tier run");
    assert_eq!(a.reduced_size, b.reduced_size);
    assert_eq!(a.elim_lambda.to_bits(), b.elim_lambda.to_bits());
    assert_eq!(a.components.len(), b.components.len());
    for (k, (ca, cb)) in a.components.iter().zip(&b.components).enumerate() {
        assert_eq!(ca.lambda.to_bits(), cb.lambda.to_bits(), "PC{} λ diverged", k + 1);
        assert_eq!(ca.phi.to_bits(), cb.phi.to_bits(), "PC{} φ diverged", k + 1);
        assert_eq!(ca.pc.support, cb.pc.support, "PC{} support diverged", k + 1);
        for (x, y) in ca.pc.vector.iter().zip(&cb.pc.vector) {
            assert_eq!(x.to_bits(), y.to_bits(), "PC{} loadings diverged", k + 1);
        }
        assert_eq!(
            ca.explained_variance.to_bits(),
            cb.explained_variance.to_bits(),
            "PC{} explained variance diverged",
            k + 1
        );
    }
}

#[test]
fn moments_finalize_identical_across_thread_counts() {
    let spec = lsspca::corpus::CorpusSpec::nytimes().scaled(300, 9000);
    let corpus = lsspca::corpus::SynthCorpus::new(spec, 12);
    let mut m = lsspca::moments::FeatureMoments::new(9000);
    for d in 0..300 {
        m.push_doc(&corpus.generate_doc(d));
    }
    let f1 = m.finalize_par(1);
    let f4 = m.finalize_par(4);
    assert_eq!(f1.variance, f4.variance);
    assert_eq!(f1.mean, f4.mean);
    assert_eq!(f1.second_moment, f4.second_moment);
}
