//! Integration pins for the incremental-corpus subsystem (`lsspca::incr`):
//!
//! (1) forcing the drift gate (`drift_tol = 0`) makes append + refit
//!     **bitwise-identical** to a cold run over the concatenated corpus
//!     on all four covariance backends,
//! (2) a 1% append + warm refit re-reads **zero** bytes of the original
//!     corpus (instrumented via `CountingProgress`) and reuses the
//!     elimination plan and per-component λs,
//! (3) a fold killed mid-append resumes bitwise from its persisted
//!     `KIND_APPEND` job state — and job state of the wrong kind is
//!     rejected, not adopted,
//! (4) a corrupt segment is quarantined to the dead-letter queue within
//!     budget (or rejected in strict mode) without ever advancing the
//!     chained corpus digest on failure,
//! (5) end-to-end: the `watch` daemon appends, refits and atomically
//!     rewrites the artifact while a live server hot-swaps it with zero
//!     dropped requests.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lsspca::checkpoint;
use lsspca::config::PipelineConfig;
use lsspca::coordinator::ComponentReport;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::deadletter::{DeadLetterQueue, RecordPolicy};
use lsspca::error::LsspcaError;
use lsspca::incr::watch::{watch_corpus, WatchOptions};
use lsspca::incr::{chain_digest, IncrState};
use lsspca::jobstate::{self, JobState, KIND_APPEND, KIND_VARIANCE};
use lsspca::model::Model;
use lsspca::moments::FeatureMoments;
use lsspca::serve::{Server, ServerBuilder, ServerHandle};
use lsspca::session::{CountingProgress, LambdaSpec, Progress, Session, Stage};
use lsspca::stream::{FileSource, SynthSource};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_incr_{}_{name}", std::process::id()));
    p
}

/// The corpus digest the session derives for a synthetic config — same
/// identity string, same FNV fold as `resolve_corpus`.
fn synth_digest(cfg: &PipelineConfig) -> u64 {
    let spec = CorpusSpec::preset(&cfg.synth_preset)
        .unwrap()
        .scaled(cfg.synth_docs, cfg.synth_vocab);
    let c = SynthCorpus::new(spec, cfg.seed);
    checkpoint::corpus_key(&format!(
        "synth:{}:{}:{}:{}",
        c.spec.name, c.spec.num_docs, c.spec.vocab_size, c.seed
    ))
}

fn assert_components_bitwise(a: &[ComponentReport], b: &[ComponentReport]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.lambda.to_bits(), y.lambda.to_bits());
        assert_eq!(x.phi.to_bits(), y.phi.to_bits());
        assert_eq!(x.pc.support, y.pc.support);
        assert_eq!(x.pc.vector.len(), y.pc.vector.len());
        for (u, v) in x.pc.vector.iter().zip(&y.pc.vector) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        assert_eq!(x.words, y.words);
    }
}

// -- (1) drift-forced refit is bitwise a cold run, on every backend ---------

#[test]
fn forced_drift_refit_matches_cold_run_bitwise_on_all_backends() {
    let make_cfg = |docs: usize, dir: &PathBuf, backend: &str| PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: docs,
        synth_vocab: 800,
        // The incremental reduce folds the canonical CSR, which is
        // documented bitwise-equal to a workers = 1 covariance pass —
        // the cold side must run the same schedule-free shape.
        workers: 1,
        chunk_docs: 64,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 32,
        bca_sweeps: 4,
        cov_backend: backend.into(),
        // A cache dir routes the cold variance pass through the
        // deterministic resumable fold — the same chunk-ordered merge
        // the incremental bootstrap performs.
        cache_dir: dir.display().to_string(),
        incr_drift_tol: 0.0, // any variance shift re-runs elimination
        ..Default::default()
    };

    for backend in ["dense", "gram", "disk", "auto"] {
        let inc_dir = tmp(&format!("parity_inc_{backend}"));
        let cold_dir = tmp(&format!("parity_cold_{backend}"));
        std::fs::remove_dir_all(&inc_dir).ok();
        std::fs::remove_dir_all(&cold_dir).ok();

        // Incremental: fit the 300-doc base, append 60 docs, refit.
        let cfg_inc = make_cfg(300, &inc_dir, backend);
        let grown = SynthCorpus::new(CorpusSpec::nytimes().scaled(360, 800), cfg_inc.seed);
        let mut inc = Session::from_config(cfg_inc).unwrap();
        let first = inc.refit_incremental().unwrap();
        assert_eq!(first.components.len(), 2, "{backend}");
        let mut seg = SynthSource::starting_at(&grown, 300);
        let rep = inc.append(&mut seg, "parity-segment").unwrap();
        assert_eq!(rep.docs, 60, "{backend}");
        assert!(rep.drift, "{backend}: drift_tol = 0 must force re-elimination");
        let refit = inc.refit_incremental().unwrap();

        // Cold: a fresh one-shot fit of the 360-doc concatenated corpus.
        let cfg_cold = make_cfg(360, &cold_dir, backend);
        let spec = LambdaSpec::from_config(&cfg_cold);
        let mut cold = Session::from_config(cfg_cold).unwrap();
        let cold_fit = cold.fit(spec, 2).unwrap();

        assert_components_bitwise(&refit.components, &cold_fit.components);
        assert_eq!(refit.topic_table, cold_fit.topic_table, "{backend}");
        assert_eq!(refit.model, cold_fit.model, "{backend}");
        let (iv, cv) = (
            &inc.stats().unwrap().variances.variance,
            &cold.stats().unwrap().variances.variance,
        );
        assert_eq!(iv.len(), cv.len());
        for (a, b) in iv.iter().zip(cv) {
            assert_eq!(a.to_bits(), b.to_bits(), "{backend}: merged variances drifted");
        }

        std::fs::remove_dir_all(&inc_dir).ok();
        std::fs::remove_dir_all(&cold_dir).ok();
    }
}

// -- (2) 1% append + warm refit: zero re-reads, plan + λ reuse --------------

#[test]
fn one_percent_append_refits_with_zero_corpus_rereads() {
    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 2000,
        synth_vocab: 1200,
        workers: 2,
        chunk_docs: 128,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 32,
        bca_sweeps: 4,
        incr_drift_tol: 0.5, // a 1% same-distribution append stays far below
        ..Default::default()
    };
    let grown = SynthCorpus::new(CorpusSpec::nytimes().scaled(2020, 1200), cfg.seed);
    let obs = Arc::new(CountingProgress::new());
    let mut session = Session::from_config(cfg).unwrap();
    session.set_observer(Arc::clone(&obs) as Arc<dyn Progress>);

    // Stage + fit the base corpus once.
    let first = session.refit_incremental().unwrap();
    let base_stream_docs = obs.docs(Stage::Stream);
    let base_reduce_reads = obs.reads(Stage::Reduce);
    let base_elim_began = obs.began(Stage::Eliminate);
    let base_evals = obs.lambda_evals();
    assert_eq!(base_stream_docs, 2000);
    assert!(base_reduce_reads > 0, "staging must stream the corpus once");

    // Append the 1% suffix and warm-refit.
    let mut seg = SynthSource::starting_at(&grown, 2000);
    let rep = session.append(&mut seg, "one-percent-segment").unwrap();
    assert_eq!(rep.docs, 20);
    assert!(!rep.drift, "a 1% same-distribution append must not fire the gate");
    let second = session.refit_incremental().unwrap();
    assert_eq!(second.model.num_docs, 2020);

    // The only corpus bytes touched were the 20 segment documents: the
    // reduce stage performed zero reads (the cached CSR was extended
    // from the replay store) and elimination never re-ran.
    assert_eq!(obs.docs(Stage::Stream), base_stream_docs + 20);
    assert_eq!(
        obs.reads(Stage::Reduce),
        base_reduce_reads,
        "append + refit must not re-read the original corpus"
    );
    assert_eq!(obs.began(Stage::Eliminate), base_elim_began, "elimination plan must be reused");
    // Warm path: each component re-solved at its remembered λ — exactly
    // one evaluation per PC, no search.
    assert_eq!(obs.lambda_evals(), base_evals + 2);
    for (a, b) in first.components.iter().zip(&second.components) {
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "warm refit must reuse λ");
    }
}

// -- (3) kill mid-append, resume bitwise from job state ---------------------

#[test]
fn append_killed_mid_fold_resumes_bitwise_from_job_state() {
    let make_cfg = |dir: &PathBuf| PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 128,
        synth_vocab: 600,
        workers: 2,
        chunk_docs: 64,
        num_pcs: 1,
        target_card: 5,
        card_slack: 2,
        max_reduced: 32,
        bca_sweeps: 4,
        cache_dir: dir.display().to_string(),
        robust_job_state_chunks: 1,
        ..Default::default()
    };
    let cache_a = tmp("resume_clean");
    let cache_b = tmp("resume_killed");
    let cache_c = tmp("resume_foreign");
    for d in [&cache_a, &cache_b, &cache_c] {
        std::fs::remove_dir_all(d).ok();
    }
    let cfg_a = make_cfg(&cache_a);
    let grown = SynthCorpus::new(CorpusSpec::nytimes().scaled(320, 600), cfg_a.seed);
    let chained = chain_digest(synth_digest(&cfg_a), checkpoint::corpus_key("kill-seg"));

    // Reference: one uninterrupted append.
    let mut a = Session::from_config(cfg_a.clone()).unwrap();
    let rep_a = a.append(&mut SynthSource::starting_at(&grown, 128), "kill-seg").unwrap();
    assert_eq!(rep_a.docs, 192);
    assert_eq!(rep_a.digest, chained, "chained digest must be H(base ‖ segment)");
    let var_a = a.stats().unwrap().variances.variance.clone();

    // Reconstruct the moment-in-time a SIGKILL mid-fold leaves behind:
    // drive the fold directly, capture the first persisted snapshot,
    // then die on the second.
    let base = SynthCorpus::new(CorpusSpec::nytimes().scaled(128, 600), cfg_a.seed);
    let (mut st, _) = IncrState::bootstrap(&mut SynthSource::new(&base), 64, 0).unwrap();
    let saved: std::cell::RefCell<Option<(FeatureMoments, u64)>> = std::cell::RefCell::new(None);
    let err = st
        .append_docs(
            &mut SynthSource::starting_at(&grown, 128),
            1,
            |m, done| {
                if saved.borrow().is_some() {
                    return Err(LsspcaError::io("simulated kill"));
                }
                *saved.borrow_mut() = Some((m.clone(), done));
                Ok(())
            },
            0,
        )
        .unwrap_err();
    assert!(format!("{err}").contains("simulated kill"));
    let (moments, done) = saved.into_inner().unwrap();
    assert_eq!(done, 3, "base = 2 complete chunks; first segment chunk is the 3rd");
    jobstate::save(
        &jobstate::path_for(&cache_b, chained),
        &JobState {
            key: chained,
            kind: KIND_APPEND,
            chunk_docs: 64,
            completed_chunks: done,
            moments,
        },
    )
    .unwrap();

    // Restart: the session adopts the job state, folds only the docs it
    // does not cover, and lands bitwise on the uninterrupted result.
    let mut b = Session::from_config(make_cfg(&cache_b)).unwrap();
    let rep_b = b.append(&mut SynthSource::starting_at(&grown, 128), "kill-seg").unwrap();
    assert_eq!(rep_b.docs, rep_a.docs);
    assert_eq!(rep_b.nnz, rep_a.nnz);
    assert_eq!(rep_b.digest, rep_a.digest);
    let var_b = &b.stats().unwrap().variances.variance;
    for (x, y) in var_a.iter().zip(var_b) {
        assert_eq!(x.to_bits(), y.to_bits(), "resumed fold must be bitwise identical");
    }
    assert!(
        !jobstate::path_for(&cache_b, chained).exists(),
        "job state is removed once the append commits"
    );

    // Job state of the wrong kind at the right path (a variance pass
    // crashed under the same digest) is rejected, not adopted.
    jobstate::save(
        &jobstate::path_for(&cache_c, chained),
        &JobState {
            key: chained,
            kind: KIND_VARIANCE,
            chunk_docs: 64,
            completed_chunks: 3,
            moments: FeatureMoments::new(600),
        },
    )
    .unwrap();
    let mut c = Session::from_config(make_cfg(&cache_c)).unwrap();
    let rep_c = c.append(&mut SynthSource::starting_at(&grown, 128), "kill-seg").unwrap();
    assert_eq!(rep_c.digest, rep_a.digest);
    let var_c = &c.stats().unwrap().variances.variance;
    for (x, y) in var_a.iter().zip(var_c) {
        assert_eq!(x.to_bits(), y.to_bits(), "foreign-kind job state must be ignored");
    }

    for d in [&cache_a, &cache_b, &cache_c] {
        std::fs::remove_dir_all(d).ok();
    }
}

// -- (4) corrupt segments: DLQ within budget, digest never poisoned ---------

#[test]
fn corrupt_segment_quarantines_without_poisoning_chained_digest() {
    let root = tmp("corrupt_seg");
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 200,
        synth_vocab: 1000,
        workers: 2,
        chunk_docs: 64,
        num_pcs: 1,
        target_card: 5,
        card_slack: 2,
        max_reduced: 32,
        bca_sweeps: 4,
        ..Default::default()
    };
    let base_digest = synth_digest(&cfg);

    // A 40-doc segment file with three malformed records spliced in
    // front of the data section: zero doc id, out-of-range word id,
    // non-numeric count.
    let seg_path = root.join("segment.docword.txt");
    let seg_corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(40, 1000), 12345);
    seg_corpus.write_docword(&seg_path).unwrap();
    let txt = std::fs::read_to_string(&seg_path).unwrap();
    let mut lines: Vec<&str> = txt.lines().collect();
    lines.splice(3..3, ["0 5 1", "1 999999 2", "1 7 x"]);
    std::fs::write(&seg_path, lines.join("\n") + "\n").unwrap();

    let mut session = Session::from_config(cfg).unwrap();

    // Strict (no budget): the first malformed record aborts the append;
    // the clone-commit leaves digest, docs, everything untouched.
    let mut strict = FileSource::open(&seg_path).unwrap();
    let err = session.append(&mut strict, "corrupt-seg").unwrap_err();
    assert_eq!(err.exit_code(), 6, "malformed records are a corpus error: {err}");
    let stats = session.stats().unwrap();
    assert_eq!(stats.docs, 200, "failed append must not change the session");
    assert_eq!(stats.corpus_digest, base_digest, "failed append must not advance the digest");

    // With a quarantine budget the same segment folds: the three bad
    // records land in the dead-letter queue, the 40 documents append,
    // and the digest advances exactly one chain link.
    let dlq_path = root.join("dlq.jsonl");
    let policy = RecordPolicy::new(10, DeadLetterQueue::open(&dlq_path).unwrap());
    let mut lenient = FileSource::open_with_policy(&seg_path, Some(policy)).unwrap();
    let rep = session.append(&mut lenient, "corrupt-seg").unwrap();
    assert_eq!(rep.docs, 40);
    assert_eq!(lenient.bad_records(), 3);
    assert_eq!(rep.digest, chain_digest(base_digest, checkpoint::corpus_key("corrupt-seg")));
    let dlq_len = std::fs::metadata(&dlq_path).unwrap().len();
    assert!(dlq_len > 0, "quarantined records must be in the queue");

    // The session is healthy: the refit covers base + segment.
    let fit = session.refit_incremental().unwrap();
    assert_eq!(fit.model.num_docs, 240);

    std::fs::remove_dir_all(&root).ok();
}

// -- (5) e2e: watch daemon → artifact → serving hot reload ------------------

/// Read one HTTP/1.1 response (head to the blank line, then
/// `Content-Length` body bytes) off a keep-alive stream.
fn read_resp(s: &mut TcpStream) -> (u16, Vec<u8>) {
    let mut head = Vec::new();
    let mut b = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        match s.read(&mut b) {
            Ok(0) => panic!("eof mid-head: {:?}", String::from_utf8_lossy(&head)),
            Ok(_) => head.push(b[0]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => panic!("reading head: {e}"),
        }
        assert!(head.len() < 64 * 1024, "unterminated response head");
    }
    let head = String::from_utf8(head).unwrap();
    let len: usize = head
        .lines()
        .find_map(|l| {
            let (n, v) = l.split_once(':')?;
            n.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().unwrap())
        })
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    let status = head.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap();
    (status, body)
}

/// One-shot request on a fresh connection (`Connection: close`).
fn req(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    read_resp(&mut s)
}

fn start(server: Server) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let addr = server.local_addr();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().unwrap());
    (addr, handle, join)
}

#[test]
fn watch_daemon_feeds_serving_hot_reload_without_dropped_requests() {
    let dir = tmp("watch_e2e");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let input = dir.join("corpus.docword.txt");
    let model_out = dir.join("model.lspm");
    let base = SynthCorpus::new(CorpusSpec::nytimes().scaled(200, 400), 7);
    base.write_docword(&input).unwrap();

    let cfg = PipelineConfig {
        input: input.display().to_string(),
        workers: 1,
        chunk_docs: 64,
        num_pcs: 1,
        target_card: 5,
        card_slack: 2,
        max_reduced: 32,
        bca_sweeps: 4,
        incr_watch_poll_ms: 10,
        ..Default::default()
    };
    let opts = WatchOptions {
        poll: Duration::from_millis(10),
        max_refits: 2, // initial fit + one growth refresh, then exit
        model_out: model_out.clone(),
    };
    let shutdown = Arc::new(AtomicBool::new(false));
    let watch = {
        let (cfg, opts, shutdown) = (cfg.clone(), opts.clone(), Arc::clone(&shutdown));
        std::thread::spawn(move || watch_corpus(&cfg, &opts, &shutdown))
    };

    // Wait for the daemon's initial artifact, then start serving it.
    let t0 = Instant::now();
    loop {
        if let Ok(m) = Model::load(&model_out) {
            assert_eq!(m.num_docs, 200);
            break;
        }
        assert!(t0.elapsed().as_secs() < 60, "initial artifact never appeared");
        std::thread::sleep(Duration::from_millis(10));
    }
    let server = ServerBuilder::new()
        .addr("127.0.0.1:0")
        .workers(2)
        .reload_poll_ms(10)
        .register("default", &model_out)
        .default_model("default")
        .build()
        .unwrap();
    let (addr, handle, srv) = start(server);

    // Hammer the score route on keep-alive connections throughout.
    let stop = Arc::new(AtomicBool::new(false));
    let errors_5xx = Arc::new(AtomicU64::new(0));
    let requests = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for _ in 0..2 {
        let (stop, errors_5xx, requests) =
            (Arc::clone(&stop), Arc::clone(&errors_5xx), Arc::clone(&requests));
        clients.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let body = r#"{"words": [[3, 1]]}"#;
            while !stop.load(Ordering::Relaxed) {
                write!(
                    s,
                    "POST /v1/models/default/score HTTP/1.1\r\nHost: t\r\n\
                     Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                    body.len()
                )
                .unwrap();
                let (status, _) = read_resp(&mut s);
                requests.fetch_add(1, Ordering::Relaxed);
                if status >= 500 {
                    errors_5xx.fetch_add(1, Ordering::Relaxed);
                } else {
                    assert_eq!(status, 200, "unexpected status {status}");
                }
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(50));

    // Grow the corpus in place: the daemon appends the 60-doc suffix,
    // refits, atomically rewrites the artifact, and exits.
    let grown = SynthCorpus::new(CorpusSpec::nytimes().scaled(260, 400), 7);
    grown.write_docword(&input).unwrap();
    let report = watch.join().unwrap().unwrap();
    assert_eq!(report.refits, 2);
    assert_eq!(report.appends, 1);
    assert_eq!(Model::load(&model_out).unwrap().num_docs, 260);

    // The serving watcher must pick the refreshed artifact up.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = req(addr, "GET", "/v1/metrics", "");
        assert_eq!(status, 200);
        let text = String::from_utf8(body).unwrap();
        let reloads: u64 = text
            .lines()
            .find_map(|l| l.strip_prefix("lsspca_reloads_total ").map(|v| v.parse().unwrap()))
            .unwrap();
        if reloads >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "hot reload never observed:\n{text}");
        std::thread::sleep(Duration::from_millis(10));
    }

    stop.store(true, Ordering::Relaxed);
    for c in clients {
        c.join().unwrap();
    }
    assert!(requests.load(Ordering::Relaxed) > 0, "hammering never got going");
    assert_eq!(
        errors_5xx.load(Ordering::Relaxed),
        0,
        "the artifact swap must not drop a single request"
    );
    shutdown.store(true, Ordering::SeqCst);
    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
