//! Distributed sharded corpus pass conformance: coordinator + worker
//! *processes* must be bitwise identical to the single-process pipeline
//! on every covariance backend, survive worker and coordinator kills
//! with a resume that re-executes only the unfinished shards, and
//! deduplicate dead-letter quarantines across workers.
//!
//! In-process tests drive the coordinator through [`Session`] with
//! `LSSPCA_WORKER_BIN` pointed at the real `lsspca` binary (the test
//! harness executable has no `worker` subcommand to re-exec). CLI kill
//! tests re-exec the binary under `LSSPCA_FAULTS` scripts, exactly like
//! `tests/fault_tolerance.rs` — worker processes inherit the env, so
//! one variable scripts deterministic deaths anywhere in the tree.
//!
//! Artifacts land under `LSSPCA_FAULT_DIR` when set (the CI upload
//! point); on success each test removes its own directory.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::Arc;

use lsspca::config::PipelineConfig;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::session::{CountingProgress, LambdaSpec, Session, SessionBuilder, Stage};

/// Root for test artifacts: `LSSPCA_FAULT_DIR` (CI upload point) or the
/// system temp dir.
fn artifact_root() -> PathBuf {
    match std::env::var("LSSPCA_FAULT_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir(),
    }
}

fn tmp(name: &str) -> PathBuf {
    let p = artifact_root().join(format!("lsspca_dist_{}_{name}", std::process::id()));
    std::fs::create_dir_all(p.parent().unwrap()).ok();
    p
}

fn bin() -> PathBuf {
    // target/<profile>/lsspca next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("lsspca");
    p
}

/// Point the in-process coordinator at the real binary, once. Without
/// this, `dist::worker_binary()` would re-exec the *test harness*.
fn set_worker_bin() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| std::env::set_var(lsspca::dist::WORKER_BIN_ENV, bin()));
}

/// Run the binary; returns (exit code, success, stdout+stderr).
fn run_cli(args: &[&str], env: &[(&str, &str)]) -> (Option<i32>, bool, String) {
    let mut cmd = Command::new(bin());
    cmd.args(args);
    for &(k, v) in env {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("spawn lsspca");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.code(), out.status.success(), text)
}

/// 600 docs × 64-doc chunks = 10 chunks; `stream.workers = 1` so the
/// dense backend's in-process covariance pass is the sequential schedule
/// the distributed canonical-CSR replay reproduces bitwise.
fn dist_config(cache_dir: &Path, dist_workers: usize, shard_docs: u64) -> PipelineConfig {
    PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 600,
        synth_vocab: 1500,
        workers: 1,
        chunk_docs: 64,
        max_reduced: 32,
        bca_sweeps: 4,
        cache_dir: cache_dir.display().to_string(),
        dist_workers,
        dist_shard_docs: shard_docs,
        ..Default::default()
    }
}

/// The corpus digest `run_stream` derives for a synthetic config — same
/// identity string, same FNV fold.
fn synth_key(cfg: &PipelineConfig) -> u64 {
    let spec = CorpusSpec::preset(&cfg.synth_preset)
        .unwrap()
        .scaled(cfg.synth_docs, cfg.synth_vocab);
    let corpus = SynthCorpus::new(spec, cfg.seed);
    lsspca::checkpoint::corpus_key(&format!(
        "synth:{}:{}:{}:{}",
        corpus.spec.name, corpus.spec.num_docs, corpus.spec.vocab_size, corpus.seed
    ))
}

/// Find the single `.lspv` variance checkpoint in a cache dir.
fn lspv(dir: &Path) -> Option<PathBuf> {
    std::fs::read_dir(dir)
        .ok()
        .into_iter()
        .flatten()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "lspv"))
}

#[test]
fn dist_variance_is_bitwise_identical_across_worker_and_shard_counts() {
    set_worker_bin();
    let cache_ref = tmp("var_ref");
    std::fs::remove_dir_all(&cache_ref).ok();
    let cfg_ref = dist_config(&cache_ref, 0, 0);
    let key = synth_key(&cfg_ref);
    let mut sess = Session::from_config(cfg_ref).unwrap();
    let stats = sess.stream().unwrap();
    let (var_ref, mean_ref, docs_ref, nnz_ref) = (
        stats.variances.variance.clone(),
        stats.variances.mean.clone(),
        stats.docs,
        stats.nnz,
    );
    let ckpt_ref = std::fs::read(lsspca::checkpoint::path_for(&cache_ref, key)).unwrap();

    // Over the 10-chunk corpus: (1 worker, auto) → 2 shards, (2, 100
    // docs) → 5 shards, (7, 64 docs) → 10 single-chunk shards.
    for (workers, shard_docs) in [(1usize, 0u64), (2, 100), (7, 64)] {
        let cache = tmp(&format!("var_w{workers}_s{shard_docs}"));
        std::fs::remove_dir_all(&cache).ok();
        let mut sess = Session::from_config(dist_config(&cache, workers, shard_docs)).unwrap();
        let got = sess.stream().unwrap();
        assert_eq!(got.docs, docs_ref, "{workers} workers / shard_docs {shard_docs}");
        assert_eq!(got.nnz, nnz_ref, "shard merge must account every (word, count) pair");
        for (a, b) in var_ref.iter().zip(&got.variances.variance) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "variances must be bitwise identical at {workers} workers"
            );
        }
        for (a, b) in mean_ref.iter().zip(&got.variances.mean) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let ckpt = std::fs::read(lsspca::checkpoint::path_for(&cache, key)).unwrap();
        assert_eq!(
            ckpt_ref,
            ckpt,
            "checkpoint after a {workers}-worker pass must match the single-process bytes"
        );
        std::fs::remove_dir_all(&cache).ok();
    }
    std::fs::remove_dir_all(&cache_ref).ok();
}

#[test]
fn dist_fit_matches_single_process_on_every_backend() {
    set_worker_bin();
    for backend in ["dense", "gram", "disk", "auto"] {
        let cache_sp = tmp(&format!("fit_{backend}_sp"));
        let cache_dist = tmp(&format!("fit_{backend}_dist"));
        std::fs::remove_dir_all(&cache_sp).ok();
        std::fs::remove_dir_all(&cache_dist).ok();

        let fit_with = |cache: &Path, dist_workers: usize| {
            let mut cfg = dist_config(cache, dist_workers, 100);
            cfg.cov_backend = backend.into();
            let mut sess = Session::from_config(cfg).unwrap();
            sess.stream().unwrap();
            sess.fit(LambdaSpec::search(5, 2), 2).unwrap()
        };
        let sp = fit_with(&cache_sp, 0);
        let dist = fit_with(&cache_dist, 2);

        assert_eq!(sp.components.len(), dist.components.len(), "backend {backend}");
        for (a, b) in sp.components.iter().zip(&dist.components) {
            assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "λ diverged on {backend}");
            assert_eq!(a.phi.to_bits(), b.phi.to_bits(), "φ diverged on {backend}");
            assert_eq!(
                a.explained_variance.to_bits(),
                b.explained_variance.to_bits(),
                "explained variance diverged on {backend}"
            );
            assert_eq!(a.pc.support, b.pc.support, "support diverged on {backend}");
            for (x, y) in a.pc.vector.iter().zip(&b.pc.vector) {
                assert_eq!(x.to_bits(), y.to_bits(), "loadings diverged on {backend}");
            }
        }
        std::fs::remove_dir_all(&cache_sp).ok();
        std::fs::remove_dir_all(&cache_dist).ok();
    }
}

/// Shared scaffolding for the CLI kill matrix: generate a 400-doc file
/// corpus (13 chunks at 32 docs; shard_docs 64 → 7 two-chunk shards)
/// and return (root, run args builder output).
fn kill_fixture(name: &str) -> (PathBuf, String, String) {
    let root = tmp(name);
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let corpus = root.join("corpus.txt.gz");
    let corpus_s = corpus.display().to_string();
    let (_, ok, text) = run_cli(
        &["gen", "--out", &corpus_s, "--preset", "nytimes", "--docs", "400", "--vocab", "1500"],
        &[],
    );
    assert!(ok, "{text}");
    // chunk_docs is a config-file knob; the dist knobs ride on flags
    // because `PipelineConfig::load` validates the file *before* flag
    // overrides land (dist.workers > 0 demands a cache_dir).
    let cfg = root.join("dist.toml");
    std::fs::write(&cfg, "[stream]\nchunk_docs = 32\n").unwrap();
    (root, corpus_s, cfg.display().to_string())
}

fn kill_run_args<'a>(
    corpus: &'a str,
    cfg: &'a str,
    cache: &'a str,
    dist_workers: &'a str,
) -> Vec<&'a str> {
    vec![
        "run", "--config", cfg, "--input", corpus, "--pcs", "1", "--max-reduced", "32",
        "--cache-dir", cache, "--dist-workers", dist_workers, "--dist-shard-docs", "64",
    ]
}

#[test]
fn cli_worker_killed_mid_shard_resumes_only_that_shard_bitwise() {
    let (root, corpus_s, cfg_s) = kill_fixture("kill_worker");
    let killed = root.join("cache_killed");
    let clean = root.join("cache_clean");
    let killed_s = killed.display().to_string();
    let clean_s = clean.display().to_string();

    // Reference: a never-killed distributed run.
    let (_, ok, text) = run_cli(&kill_run_args(&corpus_s, &cfg_s, &clean_s, "1"), &[]);
    assert!(ok, "{text}");
    let ckpt_clean = std::fs::read(lspv(&clean).expect("clean checkpoint")).unwrap();

    // Kill the worker for shard 2 mid-write of its result file. Shards
    // 0-1 and 3-6 complete; the run ends with shard 2 retryable.
    let (code, ok, text) = run_cli(
        &kill_run_args(&corpus_s, &cfg_s, &killed_s, "1"),
        &[("LSSPCA_FAULTS", "wkill:distshard2@8")],
    );
    assert!(!ok, "the scripted worker kill must fail the run:\n{text}");
    assert_eq!(code, Some(6), "shard failures surface as corpus errors:\n{text}");
    assert!(text.contains("retryable"), "{text}");
    assert!(lspv(&killed).is_none(), "no checkpoint may exist after a failed pass");

    // Resume in-process with a counting observer: exactly ONE shard
    // (the failed one) streams again — adopted shards are silent.
    set_worker_bin();
    let cfg = PipelineConfig {
        input: corpus_s.clone(),
        chunk_docs: 32,
        max_reduced: 32,
        cache_dir: killed_s.clone(),
        dist_workers: 1,
        dist_shard_docs: 64,
        ..Default::default()
    };
    let obs = Arc::new(CountingProgress::new());
    let mut sess = SessionBuilder::from_config(cfg).observer(Arc::clone(&obs)).build().unwrap();
    sess.stream().unwrap();
    assert_eq!(
        obs.reads(Stage::Stream),
        1,
        "resume must re-execute only the killed shard, not re-read completed ones"
    );
    let ckpt_resumed = std::fs::read(lspv(&killed).expect("checkpoint after resume")).unwrap();
    assert_eq!(ckpt_clean, ckpt_resumed, "resumed pass must be bitwise identical");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn cli_every_worker_killed_then_rerun_matches_clean_run() {
    let (root, corpus_s, cfg_s) = kill_fixture("kill_all");
    let killed = root.join("cache_killed");
    let clean = root.join("cache_clean");
    let killed_s = killed.display().to_string();
    let clean_s = clean.display().to_string();

    // `distshard` (no index) matches every worker's result-file stream:
    // all 7 shards die in their header write, all land retryable.
    let (code, ok, text) = run_cli(
        &kill_run_args(&corpus_s, &cfg_s, &killed_s, "2"),
        &[("LSSPCA_FAULTS", "wkill:distshard@8")],
    );
    assert!(!ok, "{text}");
    assert_eq!(code, Some(6), "{text}");
    assert!(text.contains("shard(s) failed"), "{text}");

    // Faultless rerun recovers; clean reference run in a fresh cache.
    let (_, ok, text) = run_cli(&kill_run_args(&corpus_s, &cfg_s, &killed_s, "2"), &[]);
    assert!(ok, "{text}");
    let (_, ok, text) = run_cli(&kill_run_args(&corpus_s, &cfg_s, &clean_s, "2"), &[]);
    assert!(ok, "{text}");
    let a = std::fs::read(lspv(&killed).expect("checkpoint after recovery")).unwrap();
    let b = std::fs::read(lspv(&clean).expect("checkpoint of clean run")).unwrap();
    assert_eq!(a, b, "post-crash rerun must produce a bitwise-identical checkpoint");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn cli_coordinator_killed_between_merges_adopts_committed_shards() {
    let (root, corpus_s, cfg_s) = kill_fixture("kill_coord");
    let killed = root.join("cache_killed");
    let clean = root.join("cache_clean");
    let killed_s = killed.display().to_string();
    let clean_s = clean.display().to_string();

    // The coordinator's post-completion manifest update carries its own
    // fault tag, so this kills the *coordinator* right after the first
    // shard's result file is renamed into place — the
    // committed-but-unrecorded window the adoption scan covers.
    let (_, ok, text) = run_cli(
        &kill_run_args(&corpus_s, &cfg_s, &killed_s, "1"),
        &[("LSSPCA_FAULTS", "wkill:distmanifest@8")],
    );
    assert!(!ok, "the scripted coordinator kill must abort the run:\n{text}");
    assert!(lspv(&killed).is_none());

    let (_, ok, text) = run_cli(&kill_run_args(&corpus_s, &cfg_s, &killed_s, "1"), &[]);
    assert!(ok, "{text}");
    let (_, ok, text) = run_cli(&kill_run_args(&corpus_s, &cfg_s, &clean_s, "1"), &[]);
    assert!(ok, "{text}");
    let a = std::fs::read(lspv(&killed).expect("checkpoint after adoption")).unwrap();
    let b = std::fs::read(lspv(&clean).expect("checkpoint of clean run")).unwrap();
    assert_eq!(a, b, "adopted shards must merge bitwise-identically");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn cli_worker_killed_in_shard_job_state_resumes_from_partial_shard() {
    let (root, corpus_s, cfg_s) = kill_fixture("kill_jobstate");
    let killed = root.join("cache_killed");
    let clean = root.join("cache_clean");
    let killed_s = killed.display().to_string();
    let clean_s = clean.display().to_string();

    // Workers persist per-shard job state after every chunk; dying in
    // that write leaves a partial `.part` result whose committed prefix
    // the rerun's worker resumes instead of restarting the shard.
    let (code, ok, text) = run_cli(
        &kill_run_args(&corpus_s, &cfg_s, &killed_s, "1"),
        &[("LSSPCA_FAULTS", "wkill:jobstate@8")],
    );
    assert!(!ok, "{text}");
    assert_eq!(code, Some(6), "{text}");

    let (_, ok, text) = run_cli(&kill_run_args(&corpus_s, &cfg_s, &killed_s, "1"), &[]);
    assert!(ok, "{text}");
    let (_, ok, text) = run_cli(&kill_run_args(&corpus_s, &cfg_s, &clean_s, "1"), &[]);
    assert!(ok, "{text}");
    let a = std::fs::read(lspv(&killed).expect("checkpoint after resume")).unwrap();
    let b = std::fs::read(lspv(&clean).expect("checkpoint of clean run")).unwrap();
    assert_eq!(a, b, "partial-shard resume must be bitwise identical");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn cli_dist_dead_letter_dedups_across_workers_and_matches_single_process() {
    let root = tmp("dist_dlq");
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let corpus = root.join("corpus.txt");
    let corpus_s = corpus.display().to_string();
    let (_, ok, text) = run_cli(
        &["gen", "--out", &corpus_s, "--preset", "nytimes", "--docs", "300", "--vocab", "1200"],
        &[],
    );
    assert!(ok, "{text}");
    // Three malformed records at the top of the data section — inside
    // shard 0's range, but *every* worker re-reads this prefix while
    // seeking to its own shard, so each quarantines all three.
    let txt = std::fs::read_to_string(&corpus).unwrap();
    let mut lines: Vec<&str> = txt.lines().collect();
    lines.splice(3..3, ["0 5 1", "1 999999 2", "1 7 x"]);
    std::fs::write(&corpus, lines.join("\n") + "\n").unwrap();
    let cfg = root.join("dist.toml");
    std::fs::write(&cfg, "[stream]\nchunk_docs = 32\n").unwrap();
    let cfg_s = cfg.display().to_string();

    // Distributed run, 5 shards × 2 workers: completes, and the merged
    // queue holds each bad record ONCE (offset dedup), not once per
    // worker that saw it.
    let cache = root.join("cache_dist");
    let cache_s = cache.display().to_string();
    let dlq = root.join("dlq.jsonl");
    let dlq_s = dlq.display().to_string();
    let (_, ok, text) = run_cli(
        &[
            "run", "--config", &cfg_s, "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32",
            "--cache-dir", &cache_s, "--dist-workers", "2", "--dist-shard-docs", "64",
            "--max-bad-records", "10", "--dead-letter-path", &dlq_s,
        ],
        &[],
    );
    assert!(ok, "{text}");
    assert!(text.contains("quarantined"), "{text}");

    let (_, ok, text) = run_cli(&["dlq", "--path", &dlq_s], &[]);
    assert!(ok, "{text}");
    assert!(text.contains("3 quarantined records"), "cross-worker dedup failed:\n{text}");
    for reason in ["zero-id", "word-out-of-range", "bad-count"] {
        assert!(text.contains(reason), "missing {reason}:\n{text}");
    }
    assert!(!text.contains("WARNING"), "all merged records must pass their crc:\n{text}");

    // `dlq --retry` parity: the merged queue classifies exactly like a
    // single-process one — nothing salvageable here.
    let (code, ok, text) =
        run_cli(&["dlq", "--path", &dlq_s, "--retry", "--vocab-size", "1200"], &[]);
    assert!(!ok);
    assert_eq!(code, Some(6), "{text}");
    assert!(text.contains("0 recoverable / 3 permanently malformed"), "{text}");

    // Single-process reference on the same damaged corpus: the same
    // count and classification.
    let cache_sp = root.join("cache_sp");
    let cache_sp_s = cache_sp.display().to_string();
    let dlq_sp = root.join("dlq_sp.jsonl");
    let dlq_sp_s = dlq_sp.display().to_string();
    let (_, ok, text) = run_cli(
        &[
            "run", "--config", &cfg_s, "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32",
            "--cache-dir", &cache_sp_s, "--max-bad-records", "10", "--dead-letter-path", &dlq_sp_s,
        ],
        &[],
    );
    assert!(ok, "{text}");
    let (_, ok, text) = run_cli(&["dlq", "--path", &dlq_sp_s], &[]);
    assert!(ok, "{text}");
    assert!(text.contains("3 quarantined records"), "{text}");

    // A budget below the damage fails the shards that hit it, with the
    // corpus exit code and the manifest left retryable.
    let cache_tight = root.join("cache_tight");
    let cache_tight_s = cache_tight.display().to_string();
    let dlq_tight = root.join("dlq_tight.jsonl");
    let dlq_tight_s = dlq_tight.display().to_string();
    let (code, ok, text) = run_cli(
        &[
            "run", "--config", &cfg_s, "--input", &corpus_s, "--pcs", "1", "--max-reduced", "32",
            "--cache-dir", &cache_tight_s, "--dist-workers", "2", "--dist-shard-docs", "64",
            "--max-bad-records", "2", "--dead-letter-path", &dlq_tight_s,
        ],
        &[],
    );
    assert!(!ok);
    assert_eq!(code, Some(6), "{text}");
    assert!(text.contains("shard(s) failed"), "{text}");
    std::fs::remove_dir_all(&root).ok();
}
