//! Integration pins for the staged session API:
//!
//! (a) a warm `Session::fit` at a new (λ, K) returns PCs
//!     **bitwise-identical** to a fresh one-shot run with the same
//!     parameters,
//! (b) reusing the `ReducedCorpus` across a λ grid performs **zero**
//!     docword re-reads (instrumented via the `Progress` observer),
//! (c) failures match on the structured `LsspcaError` variants
//!     (corrupt cache → `Cache`, bad config → `Config`, missing
//!     corpus → `Io`).

use std::path::PathBuf;
use std::sync::Arc;

use lsspca::config::{Document, PipelineConfig};
use lsspca::coordinator::Pipeline;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::data::shardcache::{self, ShardCacheKey};
use lsspca::data::TripletMatrix;
use lsspca::error::LsspcaError;
use lsspca::session::{CountingProgress, LambdaSpec, Progress, Session, Stage};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_session_api_{}_{name}", std::process::id()));
    p
}

/// Write a small deterministic corpus to disk (docword + vocab).
fn corpus_file(name: &str) -> PathBuf {
    let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(500, 2000), 42);
    let path = tmp(&format!("{name}.txt.gz"));
    corpus.write_docword(&path).unwrap();
    path
}

fn cleanup(path: &PathBuf) {
    std::fs::remove_file(path).ok();
    std::fs::remove_file(path.with_extension("vocab")).ok();
}

fn file_config(input: &PathBuf, num_pcs: usize) -> PipelineConfig {
    PipelineConfig {
        input: input.display().to_string(),
        workers: 2,
        chunk_docs: 128,
        num_pcs,
        target_card: 5,
        card_slack: 2,
        max_reduced: 48,
        bca_sweeps: 5,
        ..Default::default()
    }
}

fn assert_components_bitwise(
    a: &[lsspca::coordinator::ComponentReport],
    b: &[lsspca::coordinator::ComponentReport],
) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.lambda.to_bits(), y.lambda.to_bits());
        assert_eq!(x.phi.to_bits(), y.phi.to_bits());
        assert_eq!(x.pc.support, y.pc.support);
        assert_eq!(x.pc.vector.len(), y.pc.vector.len());
        for (u, v) in x.pc.vector.iter().zip(&y.pc.vector) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        assert_eq!(x.words, y.words);
    }
}

// -- (a) warm fit at a new (λ, K) is bitwise a fresh one-shot run -----------

#[test]
fn warm_fit_at_new_k_bitwise_matches_fresh_oneshot() {
    let path = corpus_file("warm_k");
    // Warm a session with a K=3 search fit...
    let mut session = Session::from_config(file_config(&path, 3)).unwrap();
    let first = session.fit(LambdaSpec::search(5, 2), 3).unwrap();
    assert_eq!(first.components.len(), 3);
    // ...then re-fit at K=2 without re-streaming, and compare against a
    // completely fresh one-shot pipeline run configured for K=2.
    let warm = session.fit(LambdaSpec::search(5, 2), 2).unwrap();
    let fresh = Pipeline::new(file_config(&path, 2)).run().unwrap();
    assert_components_bitwise(&warm.components, &fresh.components);
    assert_eq!(warm.topic_table, fresh.topic_table);
    assert_eq!(warm.model, fresh.model);
    // the K=3 fit's first two components are the same solves too
    assert_components_bitwise(&warm.components, &first.components[..2]);
    cleanup(&path);
}

#[test]
fn warm_fit_at_new_lambda_bitwise_matches_fresh_session() {
    let path = corpus_file("warm_lambda");
    let mut warm = Session::from_config(file_config(&path, 2)).unwrap();
    // warm every stage with a search fit, then pick a λ the session has
    // already solved *near* but not at
    let probe = warm.fit(LambdaSpec::search(5, 2), 1).unwrap();
    let lam = 0.75 * probe.components[0].lambda;
    let warm_fit = warm.fit(LambdaSpec::Fixed(lam), 2).unwrap();
    // a fresh session running the identical fixed-λ fit from scratch
    let mut fresh = Session::from_config(file_config(&path, 2)).unwrap();
    let fresh_fit = fresh.fit(LambdaSpec::Fixed(lam), 2).unwrap();
    assert_components_bitwise(&warm_fit.components, &fresh_fit.components);
    assert_eq!(warm_fit.model, fresh_fit.model);
    for c in &warm_fit.components {
        assert_eq!(c.lambda, lam);
    }
    cleanup(&path);
}

// -- (b) λ-grid reuse performs zero docword re-reads ------------------------

#[test]
fn lambda_grid_reuse_never_rereads_docword() {
    let path = corpus_file("grid");
    let obs = Arc::new(CountingProgress::new());
    let mut session = Session::from_config(file_config(&path, 2)).unwrap();
    session.set_observer(Arc::clone(&obs) as Arc<dyn Progress>);
    // stage the corpus once: stream + reduce both read the file
    session.reduce().unwrap();
    let staged_reads = obs.corpus_reads();
    assert!(staged_reads > 0, "staging must stream the corpus");
    assert!(obs.docs(Stage::Stream) == 500 && obs.docs(Stage::Reduce) == 500);
    // a λ grid over the reduced operator's diagonal range
    let max_diag = {
        let rc = session.reduced_corpus().unwrap();
        (0..rc.n()).map(|i| rc.cov().diag(i)).fold(0.0f64, f64::max)
    };
    let grid: Vec<f64> = (1..=4).map(|i| 0.9 * max_diag * i as f64 / 5.0).collect();
    for &lam in &grid {
        let fit = session.fit(LambdaSpec::Fixed(lam), 1).unwrap();
        assert_eq!(fit.components[0].lambda, lam);
    }
    // plus a full λ-search re-fit at a new K
    session.fit(LambdaSpec::search(5, 2), 2).unwrap();
    // the docword file was never touched again
    assert_eq!(
        obs.corpus_reads(),
        staged_reads,
        "warm fits must perform zero docword re-reads"
    );
    // the observer did see the fits: λ evaluations and fit stages
    assert!(obs.lambda_evals() >= grid.len() as u64 + 2);
    assert_eq!(obs.began(Stage::Fit), grid.len() as u64 + 1);
    assert_eq!(obs.finished(Stage::Fit), grid.len() as u64 + 1);
    cleanup(&path);
}

// -- (c) error-variant matching ---------------------------------------------

#[test]
fn bad_config_is_a_config_error() {
    // unparsable document
    let e = Document::parse("not a key value line").unwrap_err();
    assert!(matches!(e, LsspcaError::Config { .. }), "{e}");
    // parsable but invalid knob combination
    let doc = Document::parse("[solver]\nengine = \"gpu\"").unwrap();
    let e = PipelineConfig::from_document(&doc).unwrap_err();
    assert!(matches!(e, LsspcaError::Config { .. }), "{e}");
    assert_eq!(e.exit_code(), 2);
    // the session builder rejects the same combination the same way
    let e = Session::builder().engine("gpu").build().unwrap_err();
    assert!(matches!(e, LsspcaError::Config { .. }), "{e}");
}

#[test]
fn corrupt_shard_cache_is_a_cache_error() {
    let dir = tmp("cache_dir");
    std::fs::create_dir_all(&dir).unwrap();
    let mut t = TripletMatrix::new(30, 8);
    for r in 0..30 {
        t.push(r, r % 8, 1.0 + r as f64);
    }
    let csr = t.to_csr();
    let key = ShardCacheKey { corpus_digest: 0xabc, elim_digest: 0xdef };
    let man = shardcache::write(&dir, &key, &csr, 30, 256).unwrap();
    // corrupt the manifest: open must fail with a Cache error
    let mpath = shardcache::manifest_path(&dir, &key);
    let mut bytes = std::fs::read(&mpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&mpath, &bytes).unwrap();
    let e = shardcache::open(&dir, &key).unwrap_err();
    assert!(matches!(e, LsspcaError::Cache { .. }), "{e}");
    assert_eq!(e.exit_code(), 4);
    // restore the manifest, then corrupt a shard instead:
    // verify_shards reports a Cache error too
    bytes[mid] ^= 0xFF;
    std::fs::write(&mpath, &bytes).unwrap();
    let spath = shardcache::shard_path(&dir, &key, 0);
    let mut sbytes = std::fs::read(&spath).unwrap();
    let smid = sbytes.len() / 2;
    sbytes[smid] ^= 0x01;
    std::fs::write(&spath, &sbytes).unwrap();
    let e = shardcache::verify_shards(&dir, &man, 1).unwrap_err();
    assert!(matches!(e, LsspcaError::Cache { .. }), "{e}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_corpus_is_an_io_error() {
    let cfg = file_config(&tmp("does_not_exist.txt.gz"), 1);
    let e = Pipeline::new(cfg).run().unwrap_err();
    assert!(matches!(e, LsspcaError::Io { .. }), "{e}");
    assert_eq!(e.exit_code(), 3);
    // the structured error still renders a useful message
    assert!(e.to_string().contains("does_not_exist"), "{e}");
}
