//! Integration: native Rust engine vs AOT/XLA artifacts (L2 JAX graph +
//! L1 Pallas kernel through PJRT). The three implementations of the same
//! algorithm (numpy ref ↔ jax graph is pinned by pytest; jax artifact ↔
//! native rust is pinned here).
//!
//! These tests need `make artifacts`; they skip (with a message) when the
//! artifacts are missing so plain `cargo test` still passes everywhere.
//! The whole file is compiled out without the `xla` feature.

#![cfg(feature = "xla")]

use std::path::PathBuf;

use lsspca::corpus::models::spiked_covariance_with_u;
use lsspca::data::SymMat;
use lsspca::engine::{bca_solve, Engine, NativeEngine, XlaEngine};
use lsspca::solver::bca::BcaOptions;
use lsspca::solver::extract::leading_sparse_pc;
use lsspca::util::rng::Rng;

fn engine() -> Option<XlaEngine> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join(".stamp").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(XlaEngine::load(&dir).expect("artifacts load"))
}

#[test]
fn sweep_agreement_exact_size() {
    let Some(mut xla) = engine() else { return };
    let mut native = NativeEngine::new();
    let mut rng = Rng::seed_from(42);
    // n = 32 hits an artifact size exactly — agreement should be tight.
    let n = 32;
    let (sigma, _) = spiked_covariance_with_u(n, 64, 4, 2.0, &mut rng);
    let lambda = 0.4;
    let opts = XlaEngine::matching_native_opts(&BcaOptions::default());
    let beta = opts.epsilon / n as f64;
    let mut xn = SymMat::identity(n);
    let mut xx = SymMat::identity(n);
    for sweep in 0..4 {
        let dn = native.bca_sweep(&mut xn, &sigma, lambda, beta, &opts).unwrap();
        let dx = xla.bca_sweep(&mut xx, &sigma, lambda, beta, &opts).unwrap();
        let mut worst = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                worst = worst.max((xn.get(i, j) - xx.get(i, j)).abs());
            }
        }
        assert!(
            worst < 1e-7,
            "sweep {sweep}: native/xla max diff {worst} (deltas {dn} vs {dx})"
        );
    }
}

#[test]
fn sweep_agreement_padded_size() {
    let Some(mut xla) = engine() else { return };
    let mut native = NativeEngine::new();
    let mut rng = Rng::seed_from(43);
    // n = 40 pads to the 64-artifact: padded coordinates perturb the trace
    // by O(pad·β/λ) — agreement is approximate but must stay tight.
    let n = 40;
    let (sigma, _) = spiked_covariance_with_u(n, 80, 4, 2.0, &mut rng);
    let lambda = 0.5;
    let opts = XlaEngine::matching_native_opts(&BcaOptions::default());
    let beta = opts.epsilon / n as f64;
    let mut xn = SymMat::identity(n);
    let mut xx = SymMat::identity(n);
    for _ in 0..3 {
        native.bca_sweep(&mut xn, &sigma, lambda, beta, &opts).unwrap();
        xla.bca_sweep(&mut xx, &sigma, lambda, beta, &opts).unwrap();
    }
    let mut worst = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            worst = worst.max((xn.get(i, j) - xx.get(i, j)).abs());
        }
    }
    assert!(worst < 1e-3, "padded agreement too loose: {worst}");
}

#[test]
fn full_solve_same_support_and_objective() {
    let Some(mut xla) = engine() else { return };
    let mut native = NativeEngine::new();
    let mut rng = Rng::seed_from(44);
    let n = 50;
    let (sigma, truth) = spiked_covariance_with_u(n, 150, 5, 8.0, &mut rng);
    let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, 16);
    let opts = BcaOptions { max_sweeps: 8, track_history: false, ..Default::default() };
    let sn = bca_solve(&mut native, &sigma, lambda, &opts).unwrap();
    let sx = bca_solve(&mut xla, &sigma, lambda, &opts).unwrap();
    assert!(
        (sn.phi - sx.phi).abs() < 1e-4 * (1.0 + sn.phi.abs()),
        "phi: native {} xla {}",
        sn.phi,
        sx.phi
    );
    let pn = leading_sparse_pc(&sn.z, 1e-3);
    let px = leading_sparse_pc(&sx.z, 1e-3);
    let mut a = pn.support.clone();
    let mut b = px.support.clone();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "support must agree across engines");
    // and both recover the planted spike
    let planted = lsspca::linalg::vec::support(&truth, 1e-9);
    let hits = a.iter().filter(|i| planted.contains(i)).count();
    assert!(hits >= 3, "spike recovery: {hits}/5");
}

#[test]
fn gram_and_power_agree() {
    let Some(mut xla) = engine() else { return };
    let mut native = NativeEngine::new();
    let mut rng = Rng::seed_from(45);
    let (m, k) = (600usize, 200usize);
    let data: Vec<f64> = (0..m * k).map(|_| rng.gauss()).collect();
    let gn = native.gram(m, k, &data).unwrap();
    let gx = xla.gram(m, k, &data).unwrap();
    for i in 0..k {
        for j in 0..k {
            assert!((gn.get(i, j) - gx.get(i, j)).abs() < 1e-9);
        }
    }
    let (sigma, _) = spiked_covariance_with_u(70, 140, 4, 3.0, &mut rng);
    let v0 = rng.gauss_vec(70);
    let (vn, ln) = native.power_iter(&sigma, &v0).unwrap();
    let (vx, lx) = xla.power_iter(&sigma, &v0).unwrap();
    assert!((ln - lx).abs() < 1e-8 * (1.0 + ln.abs()));
    let align: f64 = vn.iter().zip(&vx).map(|(a, b)| a * b).sum::<f64>().abs();
    assert!(align > 1.0 - 1e-8, "eigenvector alignment {align}");
}

#[test]
fn col_moments_agree() {
    let Some(mut xla) = engine() else { return };
    let mut native = NativeEngine::new();
    let mut rng = Rng::seed_from(46);
    // deliberately not block-aligned: 1300 rows (2 blocks), 200 cols (padded)
    let (m, n) = (1300usize, 200usize);
    let data: Vec<f64> = (0..m * n).map(|_| rng.gauss()).collect();
    let (sn, ssn) = native.col_moments(m, n, &data).unwrap();
    let (sx, ssx) = xla.col_moments(m, n, &data).unwrap();
    for j in 0..n {
        assert!((sn[j] - sx[j]).abs() < 1e-9 * (1.0 + sn[j].abs()));
        assert!((ssn[j] - ssx[j]).abs() < 1e-9 * (1.0 + ssn[j].abs()));
    }
    // variance identity matches the moments module on a dense matrix
    let var0 = ssn[0] / m as f64 - (sn[0] / m as f64).powi(2);
    assert!(var0 > 0.5 && var0 < 2.0, "gaussian column variance ~1, got {var0}");
}

#[test]
fn oversize_problem_is_clean_error() {
    let Some(mut xla) = engine() else { return };
    let sigma = SymMat::identity(600); // > largest artifact (512)
    let mut x = SymMat::identity(600);
    let opts = BcaOptions::default();
    let err = xla.bca_sweep(&mut x, &sigma, 0.1, 1e-5, &opts).unwrap_err();
    assert!(err.to_string().contains("exceeds"), "{err}");
}
