//! Robustness / failure-injection: malformed inputs must produce errors,
//! never panics or silent corruption.

use lsspca::config::{Document, PipelineConfig};
use lsspca::data::docword::DocwordReader;
use lsspca::util::check::property;
use lsspca::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_rob_{}_{name}", std::process::id()));
    p
}

#[test]
fn fuzz_docword_reader_never_panics() {
    // Random byte soup, random truncations of valid files, junk lines:
    // the reader must either parse or return Err — no panics.
    property("docword fuzz", 60, |rng| {
        let p = tmp(&format!("fuzz{}.txt", rng.below(1 << 30)));
        let kind = rng.below(3);
        let content: Vec<u8> = match kind {
            0 => (0..rng.below(400)).map(|_| rng.below(256) as u8).collect(),
            1 => {
                // valid-ish header then junk lines
                let mut s = format!("{}\n{}\n{}\n", rng.below(10), 1 + rng.below(10), rng.below(20));
                for _ in 0..rng.below(10) {
                    match rng.below(5) {
                        0 => s.push_str("1 2\n"),              // too few fields
                        1 => s.push_str("a b c\n"),            // non-numeric
                        2 => s.push_str("0 1 1\n"),            // zero-based id
                        3 => s.push_str("1 999999 1\n"),       // word out of range
                        _ => s.push_str("1 1 1\n"),            // fine
                    }
                }
                s.into_bytes()
            }
            _ => {
                // truncate a valid file at a random byte
                let full = "3\n4\n4\n1 1 2\n1 3 1\n2 2 1\n3 4 5\n".as_bytes().to_vec();
                let cut = rng.below(full.len() + 1);
                full[..cut].to_vec()
            }
        };
        std::fs::write(&p, &content).map_err(|e| e.to_string())?;
        // Must not panic; errors are fine.
        if let Ok(mut r) = DocwordReader::open(&p) {
            let mut guard = 0;
            loop {
                match r.next_chunk(4) {
                    Ok(None) | Err(_) => break,
                    Ok(Some(_)) => {
                        guard += 1;
                        if guard > 100 {
                            return Err("reader loops forever".into());
                        }
                    }
                }
            }
        }
        std::fs::remove_file(&p).ok();
        Ok(())
    });
}

#[test]
fn fuzz_toml_parser_never_panics() {
    property("toml fuzz", 120, |rng| {
        let tokens = [
            "[", "]", "=", "\"", "#", "\n", "a", "1", "1.5", "true", "x_y", " ", ",", "[sec]",
            "k = 1", "k = \"v\"", "arr = [1, 2]",
        ];
        let mut s = String::new();
        for _ in 0..rng.below(40) {
            s.push_str(tokens[rng.below(tokens.len())]);
        }
        let _ = Document::parse(&s); // Ok or Err, never panic
        Ok(())
    });
}

#[test]
fn config_from_fuzzed_documents_never_panics() {
    property("config fuzz", 60, |rng| {
        let keys = ["workers", "chunk_docs", "target_card", "epsilon", "engine", "preset"];
        let vals = ["0", "1", "-3", "99999999999999999999", "1.5", "\"native\"", "\"zzz\"", "true"];
        let mut s = String::from("[stream]\n");
        for _ in 0..rng.below(6) {
            s.push_str(&format!("{} = {}\n", keys[rng.below(keys.len())], vals[rng.below(vals.len())]));
        }
        if let Ok(doc) = Document::parse(&s) {
            let _ = PipelineConfig::from_document(&doc); // Ok or Err
        }
        Ok(())
    });
}

#[test]
fn variance_checkpoint_reused_by_pipeline() {
    use lsspca::coordinator::Pipeline;
    let cache = tmp("cache");
    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 400,
        synth_vocab: 1500,
        cache_dir: cache.display().to_string(),
        num_pcs: 1,
        max_reduced: 32,
        bca_sweeps: 4,
        ..Default::default()
    };
    let r1 = Pipeline::new(cfg.clone()).run().unwrap();
    // a checkpoint file must now exist
    let files: Vec<_> = std::fs::read_dir(&cache)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "lspv"))
        .collect();
    assert_eq!(files.len(), 1, "expected one checkpoint");
    // second run: identical results through the cache path
    let r2 = Pipeline::new(cfg.clone()).run().unwrap();
    assert_eq!(r1.reduced_size, r2.reduced_size);
    assert_eq!(r1.components[0].words, r2.components[0].words);
    assert!((r1.components[0].phi - r2.components[0].phi).abs() < 1e-12);
    // different seed → different key → does NOT reuse the stale cache
    let mut cfg3 = cfg;
    cfg3.seed += 1;
    let r3 = Pipeline::new(cfg3).run().unwrap();
    assert_eq!(r3.num_docs, 400);
    let files_after: Vec<_> = std::fs::read_dir(&cache)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "lspv"))
        .collect();
    assert_eq!(files_after.len(), 2, "new corpus identity must write a new checkpoint");
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn corrupt_checkpoint_falls_back_to_recompute() {
    use lsspca::coordinator::Pipeline;
    let cache = tmp("badcache");
    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 300,
        synth_vocab: 1200,
        cache_dir: cache.display().to_string(),
        num_pcs: 1,
        max_reduced: 24,
        bca_sweeps: 4,
        ..Default::default()
    };
    let r1 = Pipeline::new(cfg.clone()).run().unwrap();
    // corrupt every checkpoint byte-wise
    for e in std::fs::read_dir(&cache).unwrap().filter_map(|e| e.ok()) {
        let p = e.path();
        let mut b = std::fs::read(&p).unwrap();
        let mid = b.len() / 2;
        b[mid] ^= 0x55;
        std::fs::write(&p, b).unwrap();
    }
    // pipeline must warn, recompute, and still produce identical output
    let r2 = Pipeline::new(cfg).run().unwrap();
    assert_eq!(r1.components[0].words, r2.components[0].words);
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn rng_weighted_rejects_nan_free_input_only() {
    // documentation-level test: weighted() on all-zero weights would be a
    // caller bug; ensure our samplers guard via AliasTable's assert.
    let r = std::panic::catch_unwind(|| {
        lsspca::corpus::AliasTable::new(&[0.0, 0.0]);
    });
    assert!(r.is_err(), "all-zero weights must be rejected loudly");
    let mut rng = Rng::seed_from(1);
    let t = lsspca::corpus::AliasTable::new(&[1.0, 2.0]);
    for _ in 0..10 {
        assert!(t.sample(&mut rng) < 2);
    }
}
