//! Integration: cross-checks between independent solvers and the paper's
//! analytic identities, on top of the per-module unit tests.

use lsspca::corpus::models::{gaussian_factor_cov, spiked_covariance_with_u};
use lsspca::data::SymMat;
use lsspca::linalg::eig::JacobiEig;
use lsspca::solver::bca::{self, BcaOptions};
use lsspca::solver::extract::leading_sparse_pc;
use lsspca::solver::first_order::{self, FirstOrderOptions};
use lsspca::solver::lambda::{search, LambdaSearchOptions};
use lsspca::util::check::property;
use lsspca::util::rng::Rng;

#[test]
fn prop_bca_and_first_order_same_optimum() {
    // Two very different algorithms for the same convex SDP must agree.
    property("BCA φ == first-order φ (convexity)", 4, |rng| {
        let n = rng.range(4, 9);
        let sigma = SymMat::random_psd(n, 2 * n, 0.2, rng);
        let min_diag = (0..n).map(|i| sigma.get(i, i)).fold(f64::INFINITY, f64::min);
        let lambda = rng.range_f64(0.2, 0.6) * min_diag;
        let b = bca::solve(
            &sigma,
            lambda,
            &BcaOptions { max_sweeps: 80, epsilon: 1e-5, tol: 1e-11, ..Default::default() },
        );
        let f = first_order::solve(
            &sigma,
            lambda,
            &FirstOrderOptions { max_iters: 4000, epsilon: 1e-3, gap_tol: 1e-5, ..Default::default() },
        );
        lsspca::util::check::close(b.phi, f.phi, 3e-2)?;
        // BCA's φ must respect the first-order dual upper bound
        lsspca::util::check::ensure(
            b.phi <= f.dual_bound + 1e-3 * (1.0 + f.dual_bound.abs()),
            format!("BCA φ {} exceeds dual bound {}", b.phi, f.dual_bound),
        )
    });
}

#[test]
fn phi_equals_trace_of_x_star() {
    // Identity from §3: X* = φ·Z* with Tr Z* = 1 ⇒ Tr X* = φ (up to the
    // O(β·n) barrier perturbation).
    let mut rng = Rng::seed_from(55);
    let sigma = gaussian_factor_cov(12, 24, &mut rng);
    let d: Vec<f64> = (0..12).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, 6);
    let sol = bca::solve(
        &sigma,
        lambda,
        &BcaOptions { max_sweeps: 100, epsilon: 1e-6, tol: 1e-12, ..Default::default() },
    );
    let tr = sol.x.trace();
    assert!(
        (tr - sol.phi).abs() < 1e-3 * (1.0 + sol.phi.abs()),
        "Tr X* = {tr} vs φ = {}",
        sol.phi
    );
}

#[test]
fn relaxation_upper_bounds_cardinality_problem() {
    // φ (SDP value) ≥ ψ(x) = xᵀΣx − λ‖x‖₀ for any unit x — check against
    // the planted spike and the extracted PC.
    property("φ ≥ ψ(candidate) (relaxation)", 8, |rng| {
        let n = rng.range(8, 20);
        let (sigma, u) = spiked_covariance_with_u(n, 3 * n, (n / 5).max(2), 3.0, rng);
        let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
        let lambda = lsspca::elim::lambda_for_survivors(&d, n / 2);
        let sol = bca::solve(&sigma, lambda, &BcaOptions { max_sweeps: 40, ..Default::default() });
        let psi_u = sigma.quad_form(&u) - lambda * lsspca::linalg::vec::cardinality(&u, 1e-12) as f64;
        lsspca::util::check::ensure(
            sol.phi >= psi_u - 1e-5 * (1.0 + psi_u.abs()),
            format!("relaxation violated: φ={} < ψ(u)={psi_u}", sol.phi),
        )?;
        let pc = leading_sparse_pc(&sol.z, 1e-4);
        let psi_pc =
            sigma.quad_form(&pc.vector) - lambda * pc.cardinality() as f64;
        lsspca::util::check::ensure(
            sol.phi >= psi_pc - 1e-5 * (1.0 + psi_pc.abs()),
            format!("relaxation violated vs extracted PC: φ={} < {psi_pc}", sol.phi),
        )
    });
}

#[test]
fn lambda_search_monotone_cardinality() {
    // Along the search trace, cardinality must be non-increasing in λ.
    let mut rng = Rng::seed_from(66);
    let (sigma, _) = spiked_covariance_with_u(40, 120, 6, 3.0, &mut rng);
    let res = search(&sigma, &LambdaSearchOptions { target_card: 6, slack: 1, ..Default::default() });
    let mut evals = res.trace.clone();
    evals.sort_by(|a, b| a.lambda.partial_cmp(&b.lambda).unwrap());
    for w in evals.windows(2) {
        assert!(
            w[0].cardinality + 2 >= w[1].cardinality,
            "cardinality grew with λ: {:?} → {:?}",
            w[0],
            w[1]
        );
    }
}

#[test]
fn bca_beats_first_order_wallclock_on_matched_accuracy() {
    // The paper's Fig-1 claim, asserted coarsely: at n=60, BCA reaches
    // first-order's final objective at least 3× faster.
    let mut rng = Rng::seed_from(77);
    let n = 60;
    let sigma = gaussian_factor_cov(n, n / 2, &mut rng);
    let d: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, n / 2);
    let f = first_order::solve(
        &sigma,
        lambda,
        &FirstOrderOptions { max_iters: 250, epsilon: 1e-2, gap_tol: 1e-9, ..Default::default() },
    );
    let b = bca::solve(&sigma, lambda, &BcaOptions { max_sweeps: 20, ..Default::default() });
    assert!(b.phi >= f.phi - 1e-6, "BCA should at least match: {} vs {}", b.phi, f.phi);
    let t_match = b
        .history
        .iter()
        .find(|h| h.objective >= f.phi - 1e-9)
        .map(|h| h.seconds)
        .unwrap_or(b.seconds);
    assert!(
        t_match * 3.0 <= f.seconds,
        "expected ≥3× speedup: BCA {t_match:.3}s vs first-order {:.3}s",
        f.seconds
    );
}

#[test]
fn extraction_consistent_with_jacobi() {
    let mut rng = Rng::seed_from(88);
    let (sigma, _) = spiked_covariance_with_u(25, 75, 4, 4.0, &mut rng);
    let d: Vec<f64> = (0..25).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&d, 8);
    let sol = bca::solve(&sigma, lambda, &BcaOptions::default());
    // leading eigenvector via power iteration (extract) vs full Jacobi
    let pc = leading_sparse_pc(&sol.z, 0.0);
    let eig = JacobiEig::new(&sol.z);
    let align: f64 = pc
        .vector
        .iter()
        .zip(eig.vector(0))
        .map(|(a, b)| a * b)
        .sum::<f64>()
        .abs();
    assert!(align > 1.0 - 1e-6, "alignment {align}");
    assert!((pc.z_eigenvalue - eig.lambda_max()).abs() < 1e-8);
}
