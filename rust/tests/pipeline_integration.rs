//! Integration: the full coordinator pipeline over file-backed and
//! in-memory corpora, including elimination-safety end to end.

use lsspca::config::PipelineConfig;
use lsspca::coordinator::Pipeline;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::cov::covariance_from_csr;
use lsspca::elim::SafeElimination;
use lsspca::moments::FeatureMoments;
use lsspca::solver::bca::{self, BcaOptions};
use lsspca::solver::extract::leading_sparse_pc;

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_it_{}_{name}", std::process::id()));
    p
}

#[test]
fn pipeline_from_file_matches_pipeline_from_synth() {
    // Write the corpus to disk, run the pipeline from the file, and
    // compare against the in-memory run — exercises the docword reader,
    // gzip, vocab loading and both streaming passes.
    let spec = CorpusSpec::nytimes().scaled(600, 2500);
    let corpus = SynthCorpus::new(spec, 31);
    let path = tmp("pipe.txt.gz");
    corpus.write_docword(&path).unwrap();

    let base = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 600,
        synth_vocab: 2500,
        seed: 31,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 48,
        bca_sweeps: 6,
        workers: 2,
        ..Default::default()
    };
    let mem = Pipeline::new(base.clone()).run().unwrap();

    let mut from_file = base;
    from_file.input = path.display().to_string();
    let file = Pipeline::new(from_file).run().unwrap();

    assert_eq!(mem.num_docs, file.num_docs);
    assert_eq!(mem.reduced_size, file.reduced_size);
    assert_eq!(mem.components.len(), file.components.len());
    for (a, b) in mem.components.iter().zip(&file.components) {
        assert_eq!(a.words, b.words, "support words must match across sources");
        assert!((a.phi - b.phi).abs() < 1e-8);
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(path.with_extension("vocab")).ok();
}

#[test]
fn elimination_is_safe_end_to_end() {
    // Thm 2.1 end-to-end: solving the FULL problem and the REDUCED problem
    // at the same λ must give the same support and objective.
    let spec = CorpusSpec::nytimes().scaled(400, 300);
    let corpus = SynthCorpus::new(spec, 7);
    let csr = corpus.to_csr();
    let mut moments = FeatureMoments::new(300);
    for d in 0..400 {
        moments.push_doc(&corpus.generate_doc(d));
    }
    let fv = moments.finalize();
    // λ keeping ~40 features
    let lambda = lsspca::elim::lambda_for_survivors(&fv.variance, 40);
    let elim = SafeElimination::from_variances(&fv, lambda, None);
    assert!(elim.reduced() <= 40 && elim.reduced() > 5);
    assert!(!elim.capped(&fv.variance));

    let all: Vec<usize> = (0..300).collect();
    let cov_full = covariance_from_csr(&csr, &all);
    let cov_red = covariance_from_csr(&csr, &elim.kept);

    let opts = BcaOptions { max_sweeps: 30, ..Default::default() };
    let sol_full = bca::solve(&cov_full, lambda, &opts);
    let sol_red = bca::solve(&cov_red, lambda, &opts);
    assert!(
        (sol_full.phi - sol_red.phi).abs() < 1e-3 * (1.0 + sol_full.phi.abs()),
        "objective must be unchanged by safe elimination: {} vs {}",
        sol_full.phi,
        sol_red.phi
    );
    // support of the full solve must lie inside the kept set
    let pc_full = leading_sparse_pc(&sol_full.z, 1e-3);
    for &i in &pc_full.support {
        assert!(
            elim.kept.contains(&i),
            "full-problem support index {i} was eliminated — unsafe!"
        );
    }
    // and the reduced solve finds the same words
    let pc_red = leading_sparse_pc(&sol_red.z, 1e-3);
    let lifted: Vec<usize> = pc_red.support.iter().map(|&r| elim.kept[r]).collect();
    let mut a = pc_full.support.clone();
    let mut b = lifted;
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "support mismatch between full and reduced solves");
}

#[test]
fn gram_backend_matches_dense_backend() {
    // The implicit-Gram covariance backend must reproduce the dense
    // pipeline: identical supports and φ to tolerance (the two backends
    // assemble the same Σ entries in different FP summation orders).
    let base = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 700,
        synth_vocab: 2800,
        seed: 47,
        num_pcs: 3,
        target_card: 5,
        card_slack: 2,
        max_reduced: 48,
        bca_sweeps: 6,
        workers: 2,
        ..Default::default()
    };
    assert_eq!(base.cov_backend, "dense");
    let dense = Pipeline::new(base.clone()).run().unwrap();

    let mut gram_cfg = base;
    gram_cfg.cov_backend = "gram".into();
    gram_cfg.row_cache_mb = 4;
    let gram = Pipeline::new(gram_cfg).run().unwrap();

    assert_eq!(dense.reduced_size, gram.reduced_size);
    assert_eq!(dense.components.len(), gram.components.len());
    for (a, b) in dense.components.iter().zip(&gram.components) {
        assert_eq!(a.words, b.words, "support words must match across backends");
        assert!(
            (a.phi - b.phi).abs() < 1e-6 * (1.0 + a.phi.abs()),
            "phi diverged: dense {} vs gram {}",
            a.phi,
            b.phi
        );
        assert!(
            (a.explained_variance - b.explained_variance).abs()
                < 1e-6 * (1.0 + a.explained_variance.abs()),
            "explained variance diverged"
        );
    }
}

#[test]
fn gram_backend_with_tiny_row_cache_still_correct() {
    // A row cache far smaller than the row set (and the cache-disabled
    // path) must only change wall time, never results.
    let base = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 400,
        synth_vocab: 1500,
        seed: 53,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 32,
        bca_sweeps: 5,
        cov_backend: "gram".into(),
        row_cache_mb: 64,
        ..Default::default()
    };
    let big = Pipeline::new(base.clone()).run().unwrap();
    for cache_mb in [0usize, 1] {
        let mut cfg = base.clone();
        // 1 MiB ≫ 32·32·8 bytes, so shrink further via a tiny budget: the
        // knob is in MiB, so exercise 0 (disabled) and 1 (minimum).
        cfg.row_cache_mb = cache_mb;
        let run = Pipeline::new(cfg).run().unwrap();
        for (a, b) in big.components.iter().zip(&run.components) {
            assert_eq!(a.words, b.words, "cache_mb={cache_mb} changed the support");
            assert_eq!(a.phi, b.phi, "cache_mb={cache_mb} changed φ");
        }
    }
}

#[test]
fn pubmed_preset_recovers_topics() {
    let cfg = PipelineConfig {
        synth_preset: "pubmed".into(),
        synth_docs: 900,
        synth_vocab: 3000,
        num_pcs: 3,
        target_card: 5,
        card_slack: 2,
        max_reduced: 64,
        bca_sweeps: 6,
        ..Default::default()
    };
    let report = Pipeline::new(cfg).run().unwrap();
    let spec = CorpusSpec::pubmed();
    // every extracted PC should be dominated by one planted topic
    for c in &report.components {
        let best = spec
            .topics
            .iter()
            .map(|t| c.words.iter().filter(|w| t.words.contains(&w.as_str())).count())
            .max()
            .unwrap();
        assert!(
            best * 2 >= c.words.len(),
            "PC words {:?} not topic-pure",
            c.words
        );
    }
}

#[test]
fn certify_produces_small_gaps() {
    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 500,
        synth_vocab: 2000,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 48,
        bca_sweeps: 8,
        certify: true,
        ..Default::default()
    };
    let report = Pipeline::new(cfg).run().unwrap();
    for c in &report.components {
        let gap = c.certificate_gap.expect("gap requested");
        assert!(gap >= -1e-8, "dual bound below primal: {gap}");
        assert!(
            gap < 0.5 * (1.0 + c.phi.abs()),
            "PC gap suspiciously large: {gap} (phi {})",
            c.phi
        );
    }
}

#[test]
fn pipeline_rejects_bad_config() {
    let mut cfg = PipelineConfig::default();
    cfg.engine = "quantum".into();
    assert!(cfg.validate().is_err());
    let cfg2 = PipelineConfig { input: "/nonexistent/file.txt".into(), ..Default::default() };
    assert!(Pipeline::new(cfg2).run().is_err());
}
