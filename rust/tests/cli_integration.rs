//! Integration: drive the `lsspca` binary end to end through its CLI
//! (gen → variances → run), exercising argument parsing, file I/O and the
//! report rendering as a user would.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target/<profile>/lsspca next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("lsspca");
    p
}

fn run(args: &[&str]) -> (bool, String) {
    let (_, ok, text) = run_with_code(args);
    (ok, text)
}

/// Like [`run`], additionally returning the process exit code.
fn run_with_code(args: &[&str]) -> (Option<i32>, bool, String) {
    let out = Command::new(bin())
        .args(args)
        .output()
        .expect("spawn lsspca");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.code(), out.status.success(), text)
}

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_cli_{}_{name}", std::process::id()));
    p
}

#[test]
fn help_lists_commands() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    for cmd in ["run", "gen", "variances", "solve", "artifacts", "export", "score", "serve", "bench"]
    {
        assert!(text.contains(cmd), "help missing '{cmd}':\n{text}");
    }
}

#[test]
fn unknown_command_fails_cleanly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
}

#[test]
fn gen_then_variances_then_run() {
    let corpus = tmp("corpus.txt.gz");
    let corpus_str = corpus.display().to_string();
    // gen
    let (ok, text) = run(&[
        "gen",
        "--out",
        &corpus_str,
        "--preset",
        "nytimes",
        "--docs",
        "500",
        "--vocab",
        "2000",
        "--seed",
        "9",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("D=500"), "{text}");
    assert!(corpus.exists());
    // variances (Fig 2 profile over the file)
    let (ok, text) = run(&["variances", "--input", &corpus_str, "--top", "8"]);
    assert!(ok, "{text}");
    assert!(text.contains("sorted word variances"), "{text}");
    assert!(text.contains("top features by variance"), "{text}");
    // full pipeline from the file
    let (ok, text) = run(&[
        "run",
        "--input",
        &corpus_str,
        "--docs",
        "500",
        "--vocab",
        "2000",
        "--seed",
        "9",
        "--pcs",
        "2",
        "--max-reduced",
        "48",
        "--profile",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("sparse PCA report"), "{text}");
    assert!(text.contains("PC1:"), "{text}");
    assert!(text.contains("section"), "profile flag should print profile:\n{text}");
    std::fs::remove_file(&corpus).ok();
    std::fs::remove_file(corpus.with_extension("vocab")).ok();
}

#[test]
fn solve_command_spiked() {
    let (ok, text) = run(&[
        "solve", "--n", "40", "--m", "120", "--model", "spiked", "--card", "4",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("support"), "{text}");
    assert!(text.contains("objective vs time"), "{text}");
}

#[test]
fn shipped_configs_parse_and_validate() {
    // The configs/ files must always load; run them at tiny scale.
    for name in ["nytimes", "pubmed"] {
        let path = format!("{}/configs/{name}.toml", env!("CARGO_MANIFEST_DIR"));
        let cfg = lsspca::config::PipelineConfig::load(std::path::Path::new(&path))
            .unwrap_or_else(|e| panic!("{path}: {e}"));
        assert_eq!(cfg.synth_preset, name);
        assert_eq!(cfg.target_card, 5);
        cfg.validate().unwrap();
    }
}

#[test]
fn run_rejects_bad_flags() {
    let (ok, text) = run(&["run", "--engine", "gpu"]);
    assert!(!ok);
    assert!(text.contains("engine"), "{text}");
    let (ok, _) = run(&["gen"]); // missing required --out
    assert!(!ok);
}

#[test]
fn exit_codes_distinguish_error_classes() {
    // config error (invalid engine) → 2, printed via Display
    let (code, ok, text) = run_with_code(&["run", "--engine", "gpu"]);
    assert!(!ok);
    assert_eq!(code, Some(2), "{text}");
    assert!(text.contains("config error"), "{text}");
    // argument-parse errors are config errors too → 2
    let (code, _, _) = run_with_code(&["run", "--bogus-flag", "1"]);
    assert_eq!(code, Some(2));
    // io error (missing corpus file) → 3
    let missing = tmp("definitely_missing.txt.gz");
    let (code, ok, text) = run_with_code(&["run", "--input", &missing.display().to_string()]);
    assert!(!ok);
    assert_eq!(code, Some(3), "{text}");
    assert!(text.contains("io error"), "{text}");
    // io error (missing/corrupt model artifact) → 3
    let model = tmp("no_such_model.lspm");
    let (code, _, text) = run_with_code(&[
        "score",
        "--model",
        &model.display().to_string(),
        "--input",
        &missing.display().to_string(),
    ]);
    assert_eq!(code, Some(3), "{text}");
    // success stays 0
    let (code, ok, _) = run_with_code(&["--help"]);
    assert!(ok);
    assert_eq!(code, Some(0));
}
