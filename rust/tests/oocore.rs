//! Out-of-core backend integration: the shard cache and `DiskGramCov`
//! driven through the full pipeline and the CLI.
//!
//! The load-bearing pin is `disk_backend_pcs_bitwise_equal_gram`: the K
//! sparse PCs of a `--cov-backend disk` run — with a memory budget far
//! smaller than the reduced matrix, so nothing can hide in the row
//! cache — must be bit-for-bit the PCs of the in-memory `gram` run.

use std::path::PathBuf;
use std::process::Command;

use lsspca::config::PipelineConfig;
use lsspca::coordinator::{choose_elimination, plan_backend, Pipeline};
use lsspca::corpus::CorpusSpec;
use lsspca::stream::{variance_pass, StreamOptions, SynthSource};

fn tmpdir(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_oocore_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn base_config(cache_dir: &PathBuf) -> PipelineConfig {
    PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 800,
        synth_vocab: 3000,
        workers: 2,
        chunk_docs: 128,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 48,
        bca_sweeps: 4,
        cache_dir: cache_dir.display().to_string(),
        ..Default::default()
    }
}

/// Acceptance pin: `disk` (tight budget → shard streaming + zero row
/// cache) reproduces the `gram` run's components bit for bit.
#[test]
fn disk_backend_pcs_bitwise_equal_gram() {
    let dir = tmpdir("bitwise");
    let mut gram_cfg = base_config(&dir);
    gram_cfg.cov_backend = "gram".into();
    let gram = Pipeline::new(gram_cfg).run().unwrap();

    let mut disk_cfg = base_config(&dir);
    disk_cfg.cov_backend = "disk".into();
    // 1 MiB budget with 1 MiB shards → a zero-row Σ cache: every gather
    // streams from disk, so equality cannot come from cached state.
    disk_cfg.memory_budget_mb = 1;
    disk_cfg.shard_mb = 1;
    let disk = Pipeline::new(disk_cfg).run().unwrap();

    assert_eq!(gram.components.len(), disk.components.len());
    for (g, d) in gram.components.iter().zip(&disk.components) {
        assert_eq!(g.lambda.to_bits(), d.lambda.to_bits(), "λ differs");
        assert_eq!(g.phi.to_bits(), d.phi.to_bits(), "φ differs");
        assert_eq!(g.pc.support, d.pc.support, "support differs");
        for (a, b) in g.pc.vector.iter().zip(&d.pc.vector) {
            assert_eq!(a.to_bits(), b.to_bits(), "loading differs");
        }
        assert_eq!(
            g.explained_variance.to_bits(),
            d.explained_variance.to_bits(),
            "explained variance differs"
        );
    }
    // the shard cache landed in the configured directory
    let lssm = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "lssm"))
        .count();
    assert!(lssm >= 1, "expected a shard manifest under {}", dir.display());
}

/// Second run with the same corpus + elimination reuses the cache: the
/// manifest bytes are untouched and the output is identical.
#[test]
fn shard_cache_reused_across_runs() {
    let dir = tmpdir("reuse");
    let mut cfg = base_config(&dir);
    cfg.cov_backend = "disk".into();
    cfg.memory_budget_mb = 8;
    let first = Pipeline::new(cfg.clone()).run().unwrap();
    // snapshot every cache file (manifest + shards)
    let snapshot: Vec<(PathBuf, Vec<u8>)> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            let s = p.to_string_lossy().to_string();
            s.ends_with(".lssm") || s.ends_with(".lss")
        })
        .map(|p| (p.clone(), std::fs::read(&p).unwrap()))
        .collect();
    assert!(!snapshot.is_empty());
    let second = Pipeline::new(cfg).run().unwrap();
    for (path, bytes) in &snapshot {
        let now = std::fs::read(path).unwrap();
        assert_eq!(&now, bytes, "cache file {} was rewritten", path.display());
    }
    for (a, b) in first.components.iter().zip(&second.components) {
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        for (x, y) in a.pc.vector.iter().zip(&b.pc.vector) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

/// A corrupted shard cache is rejected and rebuilt, not trusted: the run
/// still completes and produces the same components.
#[test]
fn corrupt_cache_rebuilt_gracefully() {
    let dir = tmpdir("corrupt");
    let mut cfg = base_config(&dir);
    cfg.cov_backend = "disk".into();
    cfg.memory_budget_mb = 8;
    let first = Pipeline::new(cfg.clone()).run().unwrap();
    // corrupt the manifest
    let manifest = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "lssm"))
        .expect("manifest exists");
    let mut bytes = std::fs::read(&manifest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&manifest, &bytes).unwrap();
    let second = Pipeline::new(cfg.clone()).run().unwrap();
    for (a, b) in first.components.iter().zip(&second.components) {
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        assert_eq!(a.phi.to_bits(), b.phi.to_bits());
    }
    // and the rebuilt manifest verifies again
    let reread = std::fs::read(&manifest).unwrap();
    assert_ne!(reread, bytes, "manifest must have been rewritten");

    // Now corrupt a *shard* (manifest intact): the hit-time verification
    // sweep must catch it and rebuild rather than panic mid-solve.
    let shard = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "lss"))
        .expect("shard exists");
    let mut sbytes = std::fs::read(&shard).unwrap();
    let mid = sbytes.len() / 2;
    sbytes[mid] ^= 0xFF;
    std::fs::write(&shard, &sbytes).unwrap();
    let third = Pipeline::new(cfg).run().unwrap();
    for (a, b) in first.components.iter().zip(&third.components) {
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        assert_eq!(a.phi.to_bits(), b.phi.to_bits());
    }
    assert_ne!(std::fs::read(&shard).unwrap(), sbytes, "shard must have been rewritten");
}

/// The `auto` planner resolves to dense / gram / disk across three
/// budget presets of the same synthetic corpus, and each decision line
/// names the footprint estimates it was based on.
#[test]
fn planner_resolves_three_presets() {
    let c = lsspca::corpus::SynthCorpus::new(CorpusSpec::nytimes().scaled(800, 4000), 11);
    let opts = StreamOptions { workers: 2, chunk_docs: 100, queue_depth: 2 };
    let (fv, _) = variance_pass(&mut SynthSource::new(&c), opts).unwrap();
    let (elim, _) = choose_elimination(&fv, 13, 512);
    let nhat = elim.reduced() as u64;
    assert!(nhat >= 200, "n̂={nhat}");
    // workers = 30 inflates the dense assembly estimate ((workers+2)·8n̂²)
    // past gram's hard upper bound (24·n̂·m + 1 MiB row cache) by several
    // MiB, so every budget band below is guaranteed regardless of the
    // corpus draw.
    let mut cfg = PipelineConfig {
        workers: 30,
        threads: 1,
        shard_mb: 1,
        row_cache_mb: 1,
        ..Default::default()
    };
    cfg.memory_budget_mb = 1 << 20; // effectively unlimited (but set)
    let tiny = plan_backend(&fv, &elim, &cfg);
    assert_eq!(tiny.backend, "dense", "{}", tiny.describe());
    let gram_hard_cap = 24 * nhat * fv.docs + (1 << 20);
    assert!(
        tiny.gram_bytes <= gram_hard_cap && gram_hard_cap < tiny.dense_bytes,
        "estimate ordering broke: {}",
        tiny.describe()
    );
    // medium budget: at least gram's estimate, comfortably below dense's
    cfg.memory_budget_mb = tiny.gram_bytes.div_ceil(1 << 20) as usize + 1;
    assert!((cfg.memory_budget_mb as u64) < (tiny.dense_bytes >> 20), "{}", tiny.describe());
    let medium = plan_backend(&fv, &elim, &cfg);
    assert_eq!(medium.backend, "gram", "{}", medium.describe());
    // over-budget: below even gram (and the disk floor) → disk
    cfg.memory_budget_mb = 1;
    let over = plan_backend(&fv, &elim, &cfg);
    assert_eq!(over.backend, "disk", "{}", over.describe());
    for plan in [&tiny, &medium, &over] {
        let line = plan.describe();
        assert!(
            line.contains("dense≈") && line.contains("gram≈") && line.contains("disk≥"),
            "decision line must carry the estimates: {line}"
        );
    }
}

// --- CLI ---------------------------------------------------------------

fn bin() -> PathBuf {
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("lsspca");
    p
}

fn run_cli(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin()).args(args).output().expect("spawn lsspca");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

/// Acceptance: `lsspca run --cov-backend disk --memory-budget-mb <small>`
/// completes on a synthetic corpus whose reduced term matrix (as written
/// to the shard cache) exceeds the budget.
#[test]
fn cli_run_disk_backend_under_tight_budget() {
    let dir = tmpdir("cli");
    let dir_str = dir.display().to_string();
    let (ok, text) = run_cli(&[
        "run",
        "--preset",
        "nytimes",
        "--docs",
        "10000",
        "--vocab",
        "4000",
        "--pcs",
        "1",
        "--max-reduced",
        "256",
        "--cov-backend",
        "disk",
        "--memory-budget-mb",
        "3",
        "--shard-mb",
        "1",
        "--cache-dir",
        &dir_str,
    ]);
    assert!(ok, "disk-backend run failed:\n{text}");
    assert!(text.contains("PC1:"), "missing report:\n{text}");
    assert!(text.contains("shard cache written"), "no shard cache log:\n{text}");
    // the on-disk reduced matrix really exceeds the 3 MiB budget
    let cache_bytes: u64 = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let s = e.path().to_string_lossy().to_string();
            s.ends_with(".lss") || s.ends_with(".lssm")
        })
        .map(|e| e.metadata().map(|m| m.len()).unwrap_or(0))
        .sum();
    assert!(
        cache_bytes > 3 << 20,
        "corpus too small to exercise out-of-core: cache is {cache_bytes} bytes"
    );
}

/// `--cov-backend auto` logs the planner decision with its estimates.
#[test]
fn cli_auto_backend_logs_planner_decision() {
    let (ok, text) = run_cli(&[
        "run",
        "--preset",
        "nytimes",
        "--docs",
        "600",
        "--vocab",
        "2000",
        "--pcs",
        "1",
        "--max-reduced",
        "48",
        "--cov-backend",
        "auto",
        "--memory-budget-mb",
        "512",
    ]);
    assert!(ok, "auto run failed:\n{text}");
    assert!(text.contains("memory planner:"), "planner must log its decision:\n{text}");
    assert!(
        text.contains("dense≈") && text.contains("gram≈"),
        "planner log must carry footprint estimates:\n{text}"
    );
}
