//! Integration: the inference half of the system — model artifact
//! round-trip, `export` → `score` CLI bitwise reproduction, corrupted
//! artifact rejection at the user-facing level, and the HTTP scoring
//! server exercised over a real TCP socket with concurrent clients.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::process::Command;

use lsspca::config::PipelineConfig;
use lsspca::coordinator::Pipeline;
use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::model::Model;
#[allow(deprecated)]
use lsspca::score::{score_stream, BatchOptions, ScoreOptions, Scorer, ServeOptions, Server};
use lsspca::stream::SynthSource;
use lsspca::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsspca_msc_{}_{name}", std::process::id()));
    p
}

fn bin() -> PathBuf {
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("lsspca");
    p
}

fn run_bin(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin()).args(args).output().expect("spawn lsspca");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

fn tiny_config() -> PipelineConfig {
    PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: 600,
        synth_vocab: 2500,
        workers: 2,
        chunk_docs: 128,
        num_pcs: 2,
        target_card: 5,
        card_slack: 2,
        max_reduced: 48,
        bca_sweeps: 5,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Model round-trip + batch scoring determinism
// ---------------------------------------------------------------------------

#[test]
fn model_roundtrip_batch_scores_bitwise_identical() {
    let cfg = tiny_config();
    let seed = cfg.seed;
    let report = Pipeline::new(cfg).run().unwrap();
    let model = report.model.clone();
    let path = tmp("roundtrip.lspm");
    model.save(&path).unwrap();
    let loaded = Model::load(&path).unwrap();
    assert_eq!(loaded, model, "artifact round-trip must be lossless");

    // Batch-score the training corpus through the loaded artifact and
    // through the in-memory model: the CSVs must be byte-identical, and
    // each row must carry the bitwise in-memory projection.
    let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(600, 2500), seed);
    let opts = BatchOptions { threads: 2, chunk_docs: 97, top: 2 };
    let mut csv_mem = Vec::new();
    let scorer_mem = Scorer::new(&model, ScoreOptions::default()).unwrap();
    score_stream(&mut SynthSource::new(&corpus), &scorer_mem, opts, &mut csv_mem).unwrap();
    let mut csv_loaded = Vec::new();
    let scorer_loaded = Scorer::new(&loaded, ScoreOptions::default()).unwrap();
    score_stream(&mut SynthSource::new(&corpus), &scorer_loaded, opts, &mut csv_loaded).unwrap();
    assert_eq!(csv_mem, csv_loaded, "loaded artifact must score byte-identically");

    let text = String::from_utf8(csv_mem).unwrap();
    for (d, line) in text.lines().skip(1).enumerate().step_by(53) {
        let cells: Vec<&str> = line.split(',').collect();
        let want = scorer_mem.score(&corpus.generate_doc(d)).unwrap();
        for (k, w) in want.iter().enumerate() {
            let got: f64 = cells[1 + k].parse().unwrap();
            assert_eq!(got.to_bits(), w.to_bits(), "doc {d} pc {k}");
        }
    }
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// CLI: export → score reproduces in-memory projections bitwise
// ---------------------------------------------------------------------------

#[test]
fn cli_export_then_score_reproduces_in_memory_projections() {
    let corpus_path = tmp("cli_corpus.txt.gz");
    let corpus_str = corpus_path.display().to_string();
    let (ok, text) = run_bin(&[
        "gen", "--out", &corpus_str, "--preset", "nytimes", "--docs", "400", "--vocab", "2000",
        "--seed", "11",
    ]);
    assert!(ok, "{text}");

    let model_path = tmp("cli_model.lspm");
    let model_str = model_path.display().to_string();
    let (ok, text) = run_bin(&[
        "export", "--input", &corpus_str, "--seed", "11", "--pcs", "2", "--max-reduced", "48",
        "--model-out", &model_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("wrote"), "{text}");
    assert!(model_path.exists());

    let csv_path = tmp("cli_scores.csv");
    let csv_str = csv_path.display().to_string();
    let (ok, text) = run_bin(&[
        "score", "--model", &model_str, "--input", &corpus_str, "--out", &csv_str, "--top", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("docs/s"), "{text}");

    // Reference: the same projection computed in-process from the saved
    // artifact. Every CSV cell must parse back to the bitwise f64.
    let model = Model::load(&model_path).unwrap();
    let scorer = Scorer::new(&model, ScoreOptions::default()).unwrap();
    let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(400, 2000), 11);
    let text = std::fs::read_to_string(&csv_path).unwrap();
    let mut rows = 0;
    for line in text.lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        let doc_id: usize = cells[0].parse::<usize>().unwrap() - 1;
        let want = scorer.score(&corpus.generate_doc(doc_id)).unwrap();
        assert_eq!(cells.len(), 2 + want.len());
        for (k, w) in want.iter().enumerate() {
            let got: f64 = cells[1 + k].parse().unwrap();
            assert_eq!(got.to_bits(), w.to_bits(), "doc {doc_id} pc {k}");
        }
        rows += 1;
    }
    assert_eq!(rows, 400);

    // Corrupted artifact must be rejected with a checksum error, not
    // score garbage or panic.
    let mut bytes = std::fs::read(&model_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    let bad_path = tmp("cli_model_bad.lspm");
    std::fs::write(&bad_path, &bytes).unwrap();
    let (ok, text) = run_bin(&[
        "score", "--model", &bad_path.display().to_string(), "--input", &corpus_str,
        "--out", &csv_str,
    ]);
    assert!(!ok, "corrupt artifact accepted:\n{text}");
    assert!(text.contains("checksum") || text.contains("corrupt"), "{text}");

    for p in [&corpus_path, &model_path, &csv_path, &bad_path] {
        std::fs::remove_file(p).ok();
    }
    std::fs::remove_file(corpus_path.with_extension("vocab")).ok();
}

// ---------------------------------------------------------------------------
// HTTP server over a real socket
// ---------------------------------------------------------------------------

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(addr).expect("connect");
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\n\
         Connection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap(); // Connection: close → EOF delimits
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {buf:?}"));
    let json_body = buf.split("\r\n\r\n").nth(1).unwrap_or("");
    (status, Json::parse(json_body).unwrap_or(Json::Null))
}

#[test]
#[allow(deprecated)] // the legacy ServeOptions/Server::bind compat path, on purpose
fn server_answers_concurrent_score_requests_correctly() {
    let report = Pipeline::new(tiny_config()).run().unwrap();
    let model = report.model.clone();
    let scorer = Scorer::new(&model, ScoreOptions::default()).unwrap();
    let reference = Scorer::new(&model, ScoreOptions::default()).unwrap();
    let seed = tiny_config().seed;
    let corpus = SynthCorpus::new(CorpusSpec::nytimes().scaled(600, 2500), seed);

    let opts = ServeOptions { addr: "127.0.0.1:0".into(), pool: 2, ..Default::default() };
    let server = Server::bind(model.clone(), scorer, opts).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let srv = std::thread::spawn(move || server.run().unwrap());

    // health + topics
    let (code, v) = http(addr, "GET", "/healthz", "");
    assert_eq!(code, 200, "{v:?}");
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(v.get("pcs").and_then(Json::as_f64), Some(model.num_pcs() as f64));
    let (code, v) = http(addr, "GET", "/topics", "");
    assert_eq!(code, 200);
    let topics = v.get("topics").unwrap().as_array().unwrap();
    assert_eq!(topics.len(), model.num_pcs());
    // the served top word of PC1 is the trained one
    assert_eq!(
        topics[0].get("words").unwrap().as_array().unwrap()[0]
            .get("word")
            .and_then(Json::as_str)
            .map(str::to_string),
        Some(model.word_of(model.pcs[0].loadings[0].0))
    );

    // 4 concurrent clients × 3 docs each through a pool of 2 workers;
    // every response must equal the in-process projection exactly.
    std::thread::scope(|scope| {
        for client in 0..4usize {
            let corpus = &corpus;
            let reference = &reference;
            scope.spawn(move || {
                for r in 0..3usize {
                    let d = client * 29 + r * 7;
                    let doc = corpus.generate_doc(d);
                    let words: Vec<String> =
                        doc.iter().map(|&(w, c)| format!("[{w},{c}]")).collect();
                    let body = format!("{{\"words\": [{}], \"top\": 2}}", words.join(","));
                    let (code, v) = http(addr, "POST", "/score", &body);
                    assert_eq!(code, 200, "{v:?}");
                    let want = reference.score(&doc).unwrap();
                    let got = v.get("scores").unwrap().as_array().unwrap();
                    assert_eq!(got.len(), want.len());
                    for (g, w) in got.iter().zip(&want) {
                        assert_eq!(
                            g.as_f64().unwrap().to_bits(),
                            w.to_bits(),
                            "served score differs from in-memory"
                        );
                    }
                    let tops = v.get("top_pcs").unwrap().as_array().unwrap();
                    let want_tops = Scorer::top_pcs(&want, 2);
                    assert_eq!(tops[0].as_f64(), Some((want_tops[0] + 1) as f64));
                }
            });
        }
    });

    // error paths over the wire
    let (code, v) = http(addr, "POST", "/score", "this is not json");
    assert_eq!(code, 400);
    assert!(v.get("error").is_some());
    let (code, _) = http(addr, "GET", "/no/such/route", "");
    assert_eq!(code, 404);

    handle.shutdown();
    srv.join().unwrap();
}

#[test]
fn corrupted_artifact_rejected_on_load() {
    let report = Pipeline::new(tiny_config()).run().unwrap();
    let path = tmp("reject.lspm");
    report.model.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    // truncations and bit flips across the file must all be rejected
    for cut in [0usize, 7, good.len() / 2, good.len() - 1] {
        std::fs::write(&path, &good[..cut]).unwrap();
        assert!(Model::load(&path).is_err(), "truncation at {cut} accepted");
    }
    for at in [4usize, 12, good.len() / 3, good.len() - 2] {
        let mut bad = good.clone();
        bad[at] ^= 0x10;
        std::fs::write(&path, &bad).unwrap();
        assert!(Model::load(&path).is_err(), "bit flip at {at} accepted");
    }
    std::fs::remove_file(&path).ok();
}
