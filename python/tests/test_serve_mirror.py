"""Mirrors for the serving-layer PR's wire-contract invariants.

The event loop, registry, and reload logic are exercised by the Rust
integration suite over real sockets; what is mirrored here is the
*contract text* that ties independent files together — drift between
them compiles fine in Rust but breaks clients:

- the advertised ``V1_ROUTES`` table (``conn.rs``) must match the
  router's actual match arms — the structured 404 promises exactly
  these routes;
- every legacy shim must render through the shared registry JSON views
  and be marked ``Deprecation`` (the bitwise-parity mechanism: one
  render path, headers-only difference);
- every status code the serving layer can emit must be a label of
  ``lsspca_http_requests_total`` (``metrics.rs`` CODES), or /metrics
  would silently drop counts;
- the latency histogram's bucket bounds must be strictly ascending
  (cumulative rendering assumes it).
"""

import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parents[2]
SERVE = REPO / "rust" / "src" / "serve"


def read(name):
    return (SERVE / name).read_text(encoding="utf-8")


def v1_routes():
    block = re.search(
        r"pub const V1_ROUTES: \[&str; (\d+)\] = \[(.*?)\];", read("conn.rs"), re.S
    )
    routes = re.findall(r'"([A-Z]+ /[^"]*)"', block.group(2))
    assert len(routes) == int(block.group(1))
    return routes


def router_src():
    return re.search(r"pub fn route\(.*?\n\}", read("conn.rs"), re.S).group(0)


def test_v1_route_table_matches_router():
    routes = v1_routes()
    src = router_src()
    # static routes appear as literal (method, path) match arms
    for r in routes:
        method, path = r.split(" ", 1)
        if "{name}" in path:
            leaf = path.rsplit("/", 1)[1]
            assert f'Some((name, "{leaf}"))' in src, r
            assert f'("{method}", Some(slot))' in src, r
        else:
            assert f'("{method}", "{path}")' in src, r
    # ... and nothing extra: every /v1 literal the router dispatches on
    # a concrete method is advertised in the table
    advertised = {r.split(" ", 1)[1] for r in routes if "{name}" not in r}
    matched = set(re.findall(r'\("[A-Z]+", "(/v1/[^"]+)"\)', src))
    assert matched == advertised, matched.symmetric_difference(advertised)


def test_legacy_shims_share_views_and_are_marked_deprecated():
    src = router_src()
    # exactly the three legacy shims go through the deprecated() wrapper
    assert src.count("deprecated(") == 3
    # each shared JSON view renders both generations (legacy + v1)
    for view in ["healthz_json", "topics_json", "score_resp"]:
        assert src.count(view) >= 2, view
    helper = read("conn.rs")
    assert 'with_header("Deprecation", "true"' in helper
    assert 'rel=\\"successor-version\\"' in helper


def test_every_emitted_status_is_a_metrics_label():
    block = re.search(
        r"pub const CODES: \[u16; (\d+)\] = \[(.*?)\];", read("metrics.rs"), re.S
    )
    codes = {int(c) for c in re.findall(r"\d+", block.group(2))}
    assert len(codes) == int(block.group(1))
    emitted = {int(c) for c in re.findall(r"ParseError::new\(\s*(\d{3})", read("http.rs"))}
    emitted |= {int(c) for c in re.findall(r"json_resp\(\s*(\d{3})", read("conn.rs"))}
    emitted |= {int(c) for c in re.findall(r"Response::json\((\d{3})", read("listener.rs"))}
    emitted.add(200)  # Response::text(200, ...) metrics path
    assert emitted <= codes, emitted - codes


def test_histogram_buckets_strictly_ascending():
    m = re.search(
        r"pub const BUCKETS: \[f64; (\d+)\] =\s*\[(.*?)\];", read("metrics.rs"), re.S
    )
    vals = [float(x) for x in re.findall(r"[0-9.]+", m.group(2))]
    assert len(vals) == int(m.group(1))
    assert all(a < b for a, b in zip(vals, vals[1:]))
    assert vals[0] > 0
