"""AOT artifact emission: files exist, are valid HLO text, and the lowered
graph (executed through jax) matches the eager graph."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_emit_small(tmp_path):
    written = aot.emit(str(tmp_path), sizes=[32], gram_block=(256, 512), verbose=False)
    names = sorted(os.path.basename(p) for p in written)
    assert names == [
        "bca_sweep_n32.hlo.txt",
        "col_moments_b1024x512.hlo.txt",
        "gram_b256x512.hlo.txt",
        "power_iter_n32.hlo.txt",
    ]
    for p in written:
        text = open(p).read()
        assert text.startswith("HloModule"), p
        assert "f64" in text, "artifacts must be float64"


def test_bca_artifact_entry_signature(tmp_path):
    (path,) = [
        p
        for p in aot.emit(str(tmp_path), sizes=[32], verbose=False)
        if os.path.basename(p).startswith("bca_sweep")
    ]
    head = open(path).read(400)
    # (X, Σ, λ, β) -> (X',)
    assert "f64[32,32]" in head
    assert "->(f64[32,32]" in head.replace(" ", "")


def test_lowered_matches_eager():
    # Execute the lowered+compiled module via jax and compare to eager.
    n = 32
    lowered = aot.jax.jit(model.bca_sweep).lower(
        aot.jax.ShapeDtypeStruct((n, n), jnp.float64),
        aot.jax.ShapeDtypeStruct((n, n), jnp.float64),
        aot.jax.ShapeDtypeStruct((), jnp.float64),
        aot.jax.ShapeDtypeStruct((), jnp.float64),
    )
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    sigma = ref.random_psd(rng, n)
    lam = 0.3 * float(np.min(np.diag(sigma)))
    beta = 1e-3 / n
    x0 = np.eye(n)
    (got,) = compiled(
        jnp.asarray(x0), jnp.asarray(sigma), jnp.float64(lam), jnp.float64(beta)
    )
    want = model.bca_sweep_np(x0, sigma, lam, beta)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-11)


def test_power_iter_artifact_shapes(tmp_path):
    (path,) = [
        p
        for p in aot.emit(str(tmp_path), sizes=[32], verbose=False)
        if os.path.basename(p).startswith("power_iter")
    ]
    head = open(path).read(400)
    assert "f64[32]" in head  # v0 input / v output
