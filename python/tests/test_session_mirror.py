"""Mirrors for the staged-session PR's non-solver logic.

The session API itself is pure orchestration over kernels that earlier
mirror suites already validate (variance pass, GramCov/DiskGramCov
bitwise claims, scoring). What *is* new algorithmically — and therefore
mirrored here — is:

- ``config.rs``'s unknown-key typo detector: the Levenshtein
  edit-distance DP (two rolling rows) plus the "suggest within
  distance 2" rule;
- the CLI exit-code contract (``error.rs``): distinct codes per error
  class, matching the table documented in README.md;
- the bench-gate wiring: ``BENCH_baseline.json`` must carry a positive
  baseline for every metric ``lsspca bench --compare`` gates on
  (``main.rs``), including the new ``session_refit_median_secs`` —
  a missing key would fail CI's gate step at runtime.
"""

import json
import pathlib
import random
import re

REPO = pathlib.Path(__file__).resolve().parents[2]


# ---------------------------------------------------------------------------
# edit distance (mirror of config.rs::edit_distance)
# ---------------------------------------------------------------------------


def edit_distance(a: str, b: str) -> int:
    """Transliteration of the Rust rolling-row DP."""
    prev = list(range(len(b) + 1))
    cur = [0] * (len(b) + 1)
    for i, ca in enumerate(a):
        cur[0] = i + 1
        for j, cb in enumerate(b):
            sub = prev[j] + (ca != cb)
            cur[j + 1] = min(sub, prev[j + 1] + 1, cur[j] + 1)
        prev, cur = cur, prev
    return prev[len(b)]


def reference_distance(a: str, b: str) -> int:
    """Classic full-matrix Levenshtein, independently written."""
    m, n = len(a), len(b)
    d = [[0] * (n + 1) for _ in range(m + 1)]
    for i in range(m + 1):
        d[i][0] = i
    for j in range(n + 1):
        d[0][j] = j
    for i in range(1, m + 1):
        for j in range(1, n + 1):
            d[i][j] = min(
                d[i - 1][j] + 1,
                d[i][j - 1] + 1,
                d[i - 1][j - 1] + (a[i - 1] != b[j - 1]),
            )
    return d[m][n]


def test_edit_distance_known_values():
    assert edit_distance("memry", "memory") == 1
    assert edit_distance("target_cards", "target_card") == 1
    assert edit_distance("", "abc") == 3
    assert edit_distance("abc", "") == 3
    assert edit_distance("kitten", "sitting") == 3
    assert edit_distance("same", "same") == 0


def test_edit_distance_matches_reference_randomized():
    rng = random.Random(20110512)
    alphabet = "abcde_"
    for _ in range(300):
        a = "".join(rng.choice(alphabet) for _ in range(rng.randrange(0, 9)))
        b = "".join(rng.choice(alphabet) for _ in range(rng.randrange(0, 9)))
        got = edit_distance(a, b)
        want = reference_distance(a, b)
        assert got == want, (a, b, got, want)
        # symmetry + bounds
        assert edit_distance(b, a) == got
        assert got <= max(len(a), len(b))


def known_keys_from_rust():
    """Parse the KNOWN_KEYS whitelist out of config.rs."""
    src = (REPO / "rust" / "src" / "config.rs").read_text(encoding="utf-8")
    block = re.search(
        r"const KNOWN_KEYS: &\[\(&str, &str\)\] = &\[(.*?)\];", src, re.S
    ).group(1)
    return re.findall(r'\("([^"]+)", "([^"]+)"\)', block)


def test_known_keys_whitelist_matches_from_document():
    """Every key from_document reads must be whitelisted, and vice
    versa — a key added to one side but not the other silently warns
    (or silently stops warning)."""
    src = (REPO / "rust" / "src" / "config.rs").read_text(encoding="utf-8")
    body = re.search(
        r"pub fn from_document.*?cfg\.validate\(\)\?", src, re.S
    ).group(0)
    # \s* between the arguments: rustfmt wraps the longer calls across
    # lines, and a key must not fall out of the mirror for being wrapped.
    consumed = set(re.findall(r'doc\.\w+_or\(\s*"(\w+)",\s*"(\w+)"', body))
    whitelisted = set(known_keys_from_rust())
    assert consumed == whitelisted, (
        consumed.symmetric_difference(whitelisted)
    )


def test_typo_suggestion_rule():
    """The suggest-within-distance-2 rule points [memry] at [memory]
    and target_cards at target_card, and stays silent for unrelated
    names."""
    keys = known_keys_from_rust()
    sections = sorted({s for s, _ in keys})

    def suggest(got, candidates):
        best = min(candidates, key=lambda c: edit_distance(got, c))
        return best if edit_distance(got, best) <= 2 else None

    assert suggest("memry", sections) == "memory"
    solver_keys = [k for s, k in keys if s == "solver"]
    assert suggest("target_cards", solver_keys) == "target_card"
    assert suggest("completely_unrelated_knob", solver_keys) is None


# ---------------------------------------------------------------------------
# exit-code contract (mirror of error.rs::exit_code)
# ---------------------------------------------------------------------------

DOCUMENTED_EXIT_CODES = {
    "Config": 2,
    "Io": 3,
    "Cache": 4,
    "Numeric": 5,
    "Corpus": 6,
    "Serve": 7,
}


def test_exit_codes_match_error_rs():
    src = (REPO / "rust" / "src" / "error.rs").read_text(encoding="utf-8")
    body = re.search(
        r"pub fn exit_code\(&self\) -> i32 \{.*?\n    \}", src, re.S
    ).group(0)
    found = dict(re.findall(r"LsspcaError::(\w+) \{ \.\. \} => (\d+)", body))
    assert {k: int(v) for k, v in found.items()} == DOCUMENTED_EXIT_CODES
    # distinct, and none collides with success (0) or the generic 1
    codes = list(DOCUMENTED_EXIT_CODES.values())
    assert len(set(codes)) == len(codes)
    assert all(c >= 2 for c in codes)


# ---------------------------------------------------------------------------
# bench-gate wiring (BENCH_baseline.json ↔ main.rs --compare list)
# ---------------------------------------------------------------------------


def test_baseline_covers_every_gated_metric():
    baseline = json.loads((REPO / "BENCH_baseline.json").read_text())
    gate = baseline["gate"]
    src = (REPO / "rust" / "src" / "main.rs").read_text(encoding="utf-8")
    compare = re.search(
        r"bench_compare_gate\(\s*Path::new\(&baseline\),\s*&\[(.*?)\]", src, re.S
    ).group(1)
    gated = re.findall(r'\("([a-z0-9_]+)"', compare)
    assert "session_refit_median_secs" in gated
    for name in gated:
        assert name in gate, f"BENCH_baseline.json gate missing {name}"
        assert gate[name] > 0
    # the gate's shape keys are present for the mismatch check
    assert gate["quick"] is True and gate["n"] == 128
