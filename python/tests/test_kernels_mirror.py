"""Mirror suite for the PR-7 SIMD kernel layer (rust/src/kernels/).

Stdlib + numpy only; runs standalone:

    python3 python/tests/test_kernels_mirror.py

The Rust claims under test are *summation-order* claims about IEEE-754
doubles, so they are checkable without the Rust toolchain by replaying
the exact operation sequences in Python floats (which are IEEE doubles
with round-to-nearest-even, same as Rust f64):

1. The scalar reference dot (4-way unrolled, `(s0+s1)+(s2+s3)` tree,
   sequential tail), the AVX2 simulation (4-lane vertical accumulate,
   same tree), and the NEON simulation (two 2-lane accumulators, same
   tree) are bitwise identical at every probed length — including the
   awkward ones (0..=33, 127, 1000) and adversarial data (mixed
   magnitudes, subnormals, signed zeros).
2. The fast_math FMA variant (exact fused multiply-add emulated with
   Fraction arithmetic + one correct rounding) stays within 1e-12
   relative of the exact path on unit-scale data.
3. Skipping exact-zero scatter columns / inactive shards (the
   DiskGramCov::stream_ax bugfix) is bitwise-neutral: a +0.0-seeded
   running sum can never become -0.0, so `ax[d] += v * 0.0` is always
   the identity on bits.
4. The CSC column-sweep scatter and the CSR row-major accumulate add
   each output's terms in the same (ascending-column) order, hence
   bitwise-equal results — the GramCov::forward_ax fast-path claim.
"""

import math
import random
import struct
from fractions import Fraction

import numpy as np

PROBE_SIZES = list(range(34)) + [127, 1000]


def bits(x):
    return struct.pack("<d", float(x))


# ---------------------------------------------------------------------------
# mirrored kernels (line-for-line from rust/src/kernels/{scalar,x86,neon}.rs)
# ---------------------------------------------------------------------------


def dot_scalar(a, b):
    n = len(a)
    chunks = n // 4
    s0 = s1 = s2 = s3 = 0.0
    for k in range(chunks):
        i = 4 * k
        s0 += a[i] * b[i]
        s1 += a[i + 1] * b[i + 1]
        s2 += a[i + 2] * b[i + 2]
        s3 += a[i + 3] * b[i + 3]
    s = (s0 + s1) + (s2 + s3)
    for i in range(4 * chunks, n):
        s += a[i] * b[i]
    return s


def dot_avx2(a, b):
    # Vertical 4-lane accumulate: lane j mirrors scalar s_j exactly.
    n = len(a)
    chunks = n // 4
    lanes = [0.0, 0.0, 0.0, 0.0]
    for k in range(chunks):
        i = 4 * k
        for j in range(4):
            lanes[j] = lanes[j] + a[i + j] * b[i + j]
    s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
    for i in range(4 * chunks, n):
        s += a[i] * b[i]
    return s


def dot_neon(a, b):
    # Two 2-lane accumulators per 4-chunk; reduce4 = (s0+s1)+(s2+s3).
    n = len(a)
    chunks = n // 4
    acc01 = [0.0, 0.0]
    acc23 = [0.0, 0.0]
    for k in range(chunks):
        i = 4 * k
        acc01[0] += a[i] * b[i]
        acc01[1] += a[i + 1] * b[i + 1]
        acc23[0] += a[i + 2] * b[i + 2]
        acc23[1] += a[i + 3] * b[i + 3]
    s01 = acc01[0] + acc01[1]
    s23 = acc23[0] + acc23[1]
    s = s01 + s23
    for i in range(4 * chunks, n):
        s += a[i] * b[i]
    return s


def fma(a, b, c):
    # Exact fused multiply-add: one rounding of the exact a*b + c.
    # float(Fraction) rounds correctly to nearest-even, which is the
    # IEEE fma semantics for finite inputs.
    return float(Fraction(a) * Fraction(b) + Fraction(c))


def dot_fma(a, b):
    n = len(a)
    chunks = n // 4
    lanes = [0.0, 0.0, 0.0, 0.0]
    for k in range(chunks):
        i = 4 * k
        for j in range(4):
            lanes[j] = fma(a[i + j], b[i + j], lanes[j])
    s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
    for i in range(4 * chunks, n):
        s = fma(a[i], b[i], s)
    return s


# ---------------------------------------------------------------------------
# test data
# ---------------------------------------------------------------------------


def adversarial(rng, n):
    """Mixed magnitudes, subnormals, signed zeros — worst case for
    reassociation sensitivity."""
    out = []
    for _ in range(n):
        kind = rng.randrange(6)
        if kind == 0:
            out.append(rng.gauss(0.0, 1.0) * 10.0 ** rng.randrange(-12, 13))
        elif kind == 1:
            out.append(5e-324 * rng.randrange(1, 1000))  # subnormal
        elif kind == 2:
            out.append(-0.0 if rng.random() < 0.5 else 0.0)
        else:
            out.append(rng.gauss(0.0, 1.0))
    return out


def test_lane_tree_bitwise_identity():
    rng = random.Random(20260808)
    cases = 0
    for n in PROBE_SIZES:
        for trial in range(30 if n <= 33 else 8):
            if trial % 2 == 0:
                a = [rng.gauss(0.0, 1.0) for _ in range(n)]
                b = [rng.gauss(0.0, 1.0) for _ in range(n)]
            else:
                a = adversarial(rng, n)
                b = adversarial(rng, n)
            r = dot_scalar(a, b)
            assert bits(dot_avx2(a, b)) == bits(r), f"avx2 != scalar at n={n}"
            assert bits(dot_neon(a, b)) == bits(r), f"neon != scalar at n={n}"
            cases += 1
    print(f"  lane-tree bitwise identity: {cases} cases, 2 SIMD simulations")


def test_tree_shape_matters():
    """Sanity check that the test has teeth: the *wrong* reduction order
    ((s0+s1)+s2)+s3 does differ on adversarial data, so a reduction-tree
    slip in a SIMD port would be caught above."""
    rng = random.Random(7)
    diff = 0
    for _ in range(400):
        a = adversarial(rng, 16)
        b = adversarial(rng, 16)
        s = [0.0] * 4
        for k in range(4):
            for j in range(4):
                s[j] += a[4 * k + j] * b[4 * k + j]
        good = (s[0] + s[1]) + (s[2] + s[3])
        bad = ((s[0] + s[1]) + s[2]) + s[3]
        if bits(good) != bits(bad):
            diff += 1
    assert diff > 0, "reduction-order probe has no discriminating power"
    print(f"  tree-shape discriminator: {diff}/400 adversarial cases differ")


def test_fast_math_within_1e_12():
    rng = random.Random(42)
    worst = 0.0
    for n in [33, 127, 1000]:
        for _ in range(10):
            a = [rng.gauss(0.0, 1.0) for _ in range(n)]
            b = [rng.gauss(0.0, 1.0) for _ in range(n)]
            exact = dot_scalar(a, b)
            fused = dot_fma(a, b)
            denom = max(abs(exact), 1.0)
            worst = max(worst, abs(fused - exact) / denom)
    assert worst <= 1e-12, f"fast_math deviation {worst:.3e} > 1e-12"
    print(f"  fast_math dot vs exact: worst relative deviation {worst:.3e}")


def test_zero_skip_bitwise_neutral():
    """stream_ax / scatter_matvec_into: skipping xc == 0.0 columns (and
    all-zero shards) never changes a bit of the +0.0-seeded output."""
    rng = random.Random(99)
    for _ in range(200):
        rows, cols = rng.randrange(1, 20), rng.randrange(1, 20)
        # Column-major sparse block; values include -0.0 adversaries.
        colv = []
        for _ in range(cols):
            entries = []
            for d in range(rows):
                if rng.random() < 0.4:
                    v = rng.choice([rng.gauss(0, 1), -0.0, 0.0, -1.5])
                    entries.append((d, v))
            colv.append(entries)
        # Sparse probe: most x entries exactly 0.0 / -0.0.
        x = [
            rng.choice([0.0, -0.0]) if rng.random() < 0.7 else rng.gauss(0, 1)
            for _ in range(cols)
        ]
        full = [0.0] * rows
        for c in range(cols):
            for d, v in colv[c]:
                full[d] += v * x[c]
        skip = [0.0] * rows
        for c in range(cols):
            if x[c] == 0.0:  # matches Rust `if xc == 0.0 { continue; }`
                continue
            for d, v in colv[c]:
                skip[d] += v * x[c]
        assert all(bits(f) == bits(s) for f, s in zip(full, skip))
        # Invariant the neutrality rests on: no +0.0-seeded running sum
        # ever becomes -0.0 (so `+= v*0.0` is the bitwise identity).
        assert all(bits(f) != bits(-0.0) for f in full)
    print("  zero-column skip: bitwise-neutral on 200 blocks with -0.0 adversaries")


def test_csc_scatter_matches_csr_rows_bitwise():
    """GramCov::forward_ax: the CSC ascending-column scatter adds each
    output's terms in the same order as the CSR row-major accumulate
    (rows stored column-sorted), so the fast-path choice is free."""
    rng = random.Random(1234)
    for _ in range(120):
        rows, cols = rng.randrange(1, 30), rng.randrange(1, 30)
        csr = []
        for _ in range(rows):
            support = sorted(rng.sample(range(cols), rng.randrange(0, cols + 1)))
            csr.append([(c, rng.gauss(0, 1)) for c in support])
        x = [
            0.0 if rng.random() < 0.5 else rng.gauss(0, 1) for _ in range(cols)
        ]
        by_rows = [0.0] * rows
        for d in range(rows):
            acc = 0.0  # sequential, ascending-column (storage) order
            for c, v in csr[d]:
                acc += v * x[c]
            by_rows[d] = acc
        by_cols = [0.0] * rows
        for c in range(cols):  # ascending columns -> same per-row order
            if x[c] == 0.0:
                continue
            for d in range(rows):
                for cc, v in csr[d]:
                    if cc == c:
                        by_cols[d] += v * x[c]
        assert all(bits(r) == bits(s) for r, s in zip(by_rows, by_cols))
    print("  CSC scatter vs CSR rows: bitwise-equal on 120 random operators")


def test_numeric_agreement_with_numpy():
    rng = random.Random(5)
    for n in [127, 1000]:
        a = np.array([rng.gauss(0, 1) for _ in range(n)])
        b = np.array([rng.gauss(0, 1) for _ in range(n)])
        ours = dot_scalar(list(a), list(b))
        ref = float(np.dot(a, b))
        assert math.isclose(ours, ref, rel_tol=1e-12, abs_tol=1e-12)
    print("  scalar reference vs numpy dot: agrees to 1e-12")


if __name__ == "__main__":
    tests = [
        test_lane_tree_bitwise_identity,
        test_tree_shape_matters,
        test_fast_math_within_1e_12,
        test_zero_skip_bitwise_neutral,
        test_csc_scatter_matches_csr_rows_bitwise,
        test_numeric_agreement_with_numpy,
    ]
    for t in tests:
        print(f"{t.__name__}:")
        t()
    print(f"{len(tests)}/{len(tests)} kernel mirror tests passed")
