"""Blocked Pallas Gram kernel vs numpy."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gram import gram, gram_normalized, TILE


@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    seed=st.integers(0, 1000),
)
@settings(max_examples=8)
def test_gram_matches_numpy(mi, ni, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((mi * TILE, ni * TILE))
    got = np.asarray(gram(a))
    np.testing.assert_allclose(got, ref.gram_ref(a), atol=1e-8, rtol=1e-10)


def test_gram_normalized_scale():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((2 * TILE, TILE))
    got = np.asarray(gram_normalized(a))
    np.testing.assert_allclose(got, a.T @ a / a.shape[0], atol=1e-10)


def test_gram_rejects_unaligned():
    with pytest.raises(AssertionError):
        gram(np.zeros((100, TILE)))


def test_gram_output_symmetric_psd():
    rng = np.random.default_rng(2)
    a = rng.standard_normal((TILE, 2 * TILE))
    g = np.asarray(gram(a))
    assert np.allclose(g, g.T, atol=1e-9)
    w = np.linalg.eigvalsh(g)
    assert w.min() > -1e-8
