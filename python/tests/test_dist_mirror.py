"""Mirror of the distributed corpus-pass on-disk formats and shard plan.

``rust/src/jobstate.rs`` persists the coordinator's job state as a
``.lsjs`` dist manifest (magic ``LSJM``): identity header, the corpus
source a worker needs to reopen the *identical* stream, the kept-feature
list and the shard table, with a trailing xor-fold checksum.
``rust/src/dist/shardio.rs`` stores each shard's per-chunk accumulator
blocks as an ``.lsds`` file (magic ``LSDS``): a 64-byte identity header
followed by length-framed, checksummed blocks; a killed worker resumes
from the longest valid block prefix. ``rust/src/dist/plan.rs`` cuts the
corpus into chunk-aligned shards as a pure function of
``(num_docs, chunk_docs, shard_docs)``.

All three are cross-language contracts — an operator tool must be able
to audit a manifest or shard spill written by the Rust pipeline — so
this mirror reimplements them from the format docs alone and checks:

- the LSJM byte image against the pinned example shared with
  ``jobstate::tests::manifest_bytes_are_stable``, plus every rejection
  the Rust loader enforces (bad magic, wrong version, flipped byte,
  truncation);
- the LSDS header/block framing, roundtrip, and the longest-valid-prefix
  scan semantics (torn tail dropped, corrupt block stops the prefix,
  non-contiguous chunk index stops the prefix);
- the shard partitioner invariants (exact cover, chunk alignment,
  worker-count independence) over a seeded random sweep;
- the determinism keystone: folding per-chunk Welford accumulators in
  global chunk order is invariant to which shard computed them, while
  the hierarchical merge-shard-masters order is *not* bitwise equal —
  which is exactly why the coordinator merges per-chunk blocks.
"""

import struct

import pytest

MASK = (1 << 64) - 1


def rotl64(x, k):
    k %= 64
    return ((x << k) | (x >> (64 - k))) & MASK


def xor_fold_checksum(buf):
    """util::xor_fold_checksum — 8-byte LE lanes, zero-padded tail,
    lane ``i`` rotated left by ``i % 63`` before folding."""
    acc = 0x9E3779B97F4A7C15
    for i in range(0, len(buf), 8):
        lane = buf[i : i + 8].ljust(8, b"\x00")
        acc ^= rotl64(struct.unpack("<Q", lane)[0], (i // 8) % 63)
    return acc


def put_str(out, s):
    b = s.encode()
    out += struct.pack("<Q", len(b))
    out += b


# ---------------------------------------------------------------------------
# LSJM dist manifests (jobstate::save_dist / load_dist)
# ---------------------------------------------------------------------------

LSJM_MAGIC = b"LSJM"
LSJM_VERSION = 1
KIND_VARIANCE = 1
KIND_REDUCE = 2

# ShardStatus::to_u8
PENDING, DONE, FAILED = 0, 1, 2


def lsjm_bytes(m):
    """jobstate::save_dist's byte image. ``m["source"]`` is either
    ``("synth", preset, docs, vocab, seed)`` or ``("file", path)``."""
    out = bytearray()
    out += LSJM_MAGIC
    out += struct.pack("<I", LSJM_VERSION)
    for v in (
        m["key"],
        m["kind"],
        m["chunk_docs"],
        m["shard_docs"],
        m["num_docs"],
        m["n"],
        m["max_bad_records"],
    ):
        out += struct.pack("<Q", v)
    src = m["source"]
    if src[0] == "synth":
        out.append(0)
        put_str(out, src[1])
        for v in src[2:]:
            out += struct.pack("<Q", v)
    else:
        out.append(1)
        put_str(out, src[1])
    put_str(out, m["dead_letter"])
    out += struct.pack("<Q", len(m["kept"]))
    for f in m["kept"]:
        out += struct.pack("<I", f)
    out += struct.pack("<Q", len(m["shards"]))
    for status, attempts in m["shards"]:
        out.append(status)
        out += struct.pack("<I", attempts)
    out += struct.pack("<Q", xor_fold_checksum(bytes(out[8:])))
    return bytes(out)


def lsjm_load(buf):
    """jobstate::load_dist's validation, with the same error vocabulary."""
    if len(buf) < 16 or buf[:4] != LSJM_MAGIC:
        raise ValueError("bad magic or truncated header")
    (version,) = struct.unpack("<I", buf[4:8])
    if version != LSJM_VERSION:
        raise ValueError(f"version {version}, want {LSJM_VERSION}")
    payload = buf[8:-8]
    (stored,) = struct.unpack("<Q", buf[-8:])
    if xor_fold_checksum(payload) != stored:
        raise ValueError("checksum mismatch (corrupt file)")
    pos = 0

    def take(k):
        nonlocal pos
        if len(payload) - pos < k:
            raise ValueError("truncated payload")
        s = payload[pos : pos + k]
        pos += k
        return s

    def u64():
        return struct.unpack("<Q", take(8))[0]

    def u32():
        return struct.unpack("<I", take(4))[0]

    def string():
        return take(u64()).decode()

    m = {}
    for name in ("key", "kind", "chunk_docs", "shard_docs", "num_docs", "n", "max_bad_records"):
        m[name] = u64()
    tag = take(1)[0]
    if tag == 0:
        m["source"] = ("synth", string(), u64(), u64(), u64())
    elif tag == 1:
        m["source"] = ("file", string())
    else:
        raise ValueError(f"unknown corpus source tag {tag}")
    m["dead_letter"] = string()
    m["kept"] = [u32() for _ in range(u64())]
    shards = []
    for _ in range(u64()):
        status = take(1)[0]
        if status not in (PENDING, DONE, FAILED):
            raise ValueError(f"unknown shard status {status}")
        shards.append((status, u32()))
    m["shards"] = shards
    if pos != len(payload):
        raise ValueError("trailing bytes after shard table")
    return m


# The identical example is pinned in Rust by
# jobstate::tests::manifest_bytes_are_stable — byte image and trailing
# checksum must agree across both languages.
EXAMPLE = dict(
    key=0x1122334455667788,
    kind=KIND_REDUCE,
    chunk_docs=64,
    shard_docs=128,
    num_docs=200,
    n=1500,
    source=("synth", "nytimes", 200, 1500, 7),
    max_bad_records=2,
    dead_letter="dlq.jsonl",
    kept=[2, 5],
    shards=[(DONE, 1), (PENDING, 0)],
)
EXAMPLE_CHECKSUM = 0x069566457F40FCA7
EXAMPLE_HEX = (
    "4c534a4d0100000088776655443322110200000000000000400000000000000080000000000000"
    "00c800000000000000dc0500000000000002000000000000000007000000000000006e7974696d"
    "6573c800000000000000dc0500000000000007000000000000000900000000000000646c712e6a"
    "736f6e6c02000000000000000200000005000000020000000000000001010000000000000000a7"
    "fc407f45669506"
)


def test_lsjm_pinned_example_matches_rust():
    b = lsjm_bytes(EXAMPLE)
    assert b.hex() == EXAMPLE_HEX
    assert struct.unpack("<Q", b[-8:])[0] == EXAMPLE_CHECKSUM


def test_lsjm_roundtrip_both_sources():
    assert lsjm_load(lsjm_bytes(EXAMPLE)) == EXAMPLE
    mf = dict(EXAMPLE)
    mf["source"] = ("file", "data/docword.nytimes.txt")
    mf["kind"] = KIND_VARIANCE
    mf["kept"] = []
    mf["shards"] = [(FAILED, 2), (DONE, 1), (PENDING, 0)]
    assert lsjm_load(lsjm_bytes(mf)) == mf


def test_lsjm_rejections_match_rust_loader():
    clean = lsjm_bytes(EXAMPLE)
    with pytest.raises(ValueError, match="bad magic or truncated header"):
        lsjm_load(b"X" + clean[1:])
    with pytest.raises(ValueError, match="bad magic or truncated header"):
        lsjm_load(clean[:10])
    bumped = bytearray(clean)
    bumped[4] = 9
    with pytest.raises(ValueError, match="version 9, want 1"):
        lsjm_load(bytes(bumped))
    flipped = bytearray(clean)
    flipped[20] ^= 0x40
    with pytest.raises(ValueError, match="checksum mismatch"):
        lsjm_load(bytes(flipped))
    with pytest.raises(ValueError):
        lsjm_load(clean[: len(clean) // 3])


def test_lsjm_checksum_covers_every_byte():
    clean = lsjm_bytes(EXAMPLE)
    # every single-bit flip in the checksummed region must be caught
    for i in range(8, len(clean) - 8):
        mutated = bytearray(clean)
        mutated[i] ^= 0x01
        with pytest.raises(ValueError):
            lsjm_load(bytes(mutated))


# ---------------------------------------------------------------------------
# LSDS shard result files (dist::shardio)
# ---------------------------------------------------------------------------

LSDS_MAGIC = b"LSDS"
LSDS_VERSION = 1
HEADER_LEN = 4 + 4 + 6 * 8 + 8


def lsds_header(h):
    out = bytearray()
    out += LSDS_MAGIC
    out += struct.pack("<I", LSDS_VERSION)
    for name in ("key", "kind", "shard_index", "chunk_docs", "chunk_start", "n"):
        out += struct.pack("<Q", h[name])
    out += struct.pack("<Q", xor_fold_checksum(bytes(out[8:])))
    return bytes(out)


def lsds_block(block):
    """One length-framed block: u64 payload_len | payload | u64 checksum.
    Payload starts ``chunk_index, docs, nnz`` then the kind-specific body."""
    p = bytearray()
    for name in ("chunk_index", "docs", "nnz"):
        p += struct.pack("<Q", block[name])
    if "feats" in block:  # variance: (feature, n_obs, mean, m2) ascending
        p += struct.pack("<Q", len(block["feats"]))
        for f, n_obs, mean, m2 in block["feats"]:
            p += struct.pack("<IQdd", f, n_obs, mean, m2)
    else:  # reduce: row-major reduced CSR slab
        doc_ids, doc_ptr, idx, val = (
            block["doc_ids"],
            block["doc_ptr"],
            block["idx"],
            block["val"],
        )
        p += struct.pack("<QQ", len(doc_ids), len(idx))
        for d in doc_ids:
            p += struct.pack("<Q", d)
        for e in doc_ptr[1:]:
            p += struct.pack("<Q", e)
        for i in idx:
            p += struct.pack("<I", i)
        for x in val:
            p += struct.pack("<d", x)
    return struct.pack("<Q", len(p)) + bytes(p) + struct.pack("<Q", xor_fold_checksum(bytes(p)))


def lsds_scan(buf, expect):
    """shardio::scan — longest valid prefix whose chunk indices are
    contiguous from ``expect["chunk_start"]``. Returns (header_ok,
    chunk_indices, valid_len)."""
    if len(buf) < HEADER_LEN or buf[:HEADER_LEN] != lsds_header(expect):
        return (False, [], 0)
    chunks = []
    pos = HEADER_LEN
    nxt = expect["chunk_start"]
    valid = HEADER_LEN
    while pos + 8 <= len(buf):
        (ln,) = struct.unpack("<Q", buf[pos : pos + 8])
        end = pos + 8 + ln + 8
        if end > len(buf):
            break
        payload = buf[pos + 8 : pos + 8 + ln]
        (ck,) = struct.unpack("<Q", buf[end - 8 : end])
        if ck != xor_fold_checksum(payload) or ln < 24:
            break
        (ci, docs, _nnz) = struct.unpack("<QQQ", payload[:24])
        if ci != nxt or docs == 0:
            break
        nxt += 1
        chunks.append(ci)
        valid = end
        pos = end
    return (True, chunks, valid)


HDR = dict(key=0xABCD, kind=KIND_VARIANCE, shard_index=2, chunk_docs=64, chunk_start=6, n=1500)


def var_block(ci):
    return dict(
        chunk_index=ci,
        docs=64,
        nnz=100 + ci,
        feats=[(3, 5, 1.5, 0.25), (17, 64, -2.0, 3.5)],
    )


def test_lsds_header_is_64_bytes_and_self_checks():
    b = lsds_header(HDR)
    assert len(b) == HEADER_LEN == 64
    (stored,) = struct.unpack("<Q", b[-8:])
    assert stored == xor_fold_checksum(b[8:-8])
    # identity mismatch (different shard) → scan rejects the header
    other = dict(HDR, shard_index=3)
    ok, _, _ = lsds_scan(b, other)
    assert not ok


def test_lsds_scan_accepts_full_file_and_truncates_torn_tail():
    full = lsds_header(HDR) + b"".join(lsds_block(var_block(ci)) for ci in (6, 7, 8))
    ok, chunks, valid = lsds_scan(full, HDR)
    assert ok and chunks == [6, 7, 8] and valid == len(full)
    # a torn tail (partial last block) is dropped, completed blocks kept
    torn = full[:-5]
    ok, chunks, valid = lsds_scan(torn, HDR)
    assert ok and chunks == [6, 7]
    assert valid == len(lsds_header(HDR)) + 2 * len(lsds_block(var_block(6)))


def test_lsds_scan_stops_at_corrupt_or_noncontiguous_block():
    h = lsds_header(HDR)
    b6, b7, b8 = (lsds_block(var_block(ci)) for ci in (6, 7, 8))
    # flip one payload byte of the middle block → prefix ends after 6
    broken = bytearray(h + b6 + b7 + b8)
    broken[len(h) + len(b6) + 12] ^= 0x01
    ok, chunks, _ = lsds_scan(bytes(broken), HDR)
    assert ok and chunks == [6]
    # a gap in the chunk sequence (6 then 8) also stops the prefix
    ok, chunks, _ = lsds_scan(h + b6 + b8, HDR)
    assert ok and chunks == [6]
    # wrong starting chunk → empty prefix
    ok, chunks, _ = lsds_scan(h + b7 + b8, HDR)
    assert ok and chunks == []


def test_lsds_reduce_block_roundtrips_framing():
    hdr = dict(HDR, kind=KIND_REDUCE, n=32)
    block = dict(
        chunk_index=6,
        docs=3,
        nnz=40,
        doc_ids=[384, 385, 386],
        doc_ptr=[0, 2, 2, 5],
        idx=[0, 7, 1, 2, 31],
        val=[1.0, 2.0, 0.5, -1.0, 4.0],
    )
    buf = lsds_header(hdr) + lsds_block(block)
    ok, chunks, valid = lsds_scan(buf, hdr)
    assert ok and chunks == [6] and valid == len(buf)
    # framing sizes: 3 lens + rows + rnnz + doc_ids + row_ends + cols + vals
    payload_len = 24 + 16 + 8 * 3 + 8 * 3 + 4 * 5 + 8 * 5
    assert len(lsds_block(block)) == 8 + payload_len + 8


# ---------------------------------------------------------------------------
# Shard plan (dist::plan)
# ---------------------------------------------------------------------------


def effective_shard_docs(chunk_docs, shard_docs):
    want = 8 * chunk_docs if shard_docs == 0 else shard_docs
    return max(-(-want // chunk_docs), 1) * chunk_docs


def plan_shards(num_docs, chunk_docs, shard_docs):
    eff = effective_shard_docs(chunk_docs, shard_docs)
    cps = eff // chunk_docs
    num_chunks = -(-num_docs // chunk_docs)
    num_shards = max(-(-num_chunks // cps), 1)
    out = []
    for s in range(num_shards):
        cs, ce = s * cps, min((s + 1) * cps, num_chunks)
        out.append(
            dict(
                index=s,
                chunk_start=cs,
                chunk_end=ce,
                doc_start=min(cs * chunk_docs, num_docs),
                doc_end=min(ce * chunk_docs, num_docs),
            )
        )
    return out


def test_plan_small_cases_match_rust_tests():
    p = plan_shards(10, 4, 5)
    assert [(s["chunk_start"], s["chunk_end"]) for s in p] == [(0, 2), (2, 3)]
    assert [(s["doc_start"], s["doc_end"]) for s in p] == [(0, 8), (8, 10)]
    assert effective_shard_docs(64, 0) == 512
    assert effective_shard_docs(64, 1) == 64
    assert effective_shard_docs(64, 65) == 128
    assert effective_shard_docs(64, 128) == 128
    p = plan_shards(0, 64, 0)
    assert len(p) == 1 and p[0]["doc_end"] == 0


def test_plan_properties_over_seeded_sweep():
    # mirrors plan::tests' property sweep: exact doc cover, chunk-aligned
    # boundaries, and a pure function of its three inputs
    state = 0x00C0FFEE
    for _ in range(200):
        state = (state * 6364136223846793005 + 1442695040888963407) & MASK
        num_docs = (state >> 33) % 3000
        chunk_docs = 1 + (state >> 13) % 200
        shard_docs = (state >> 3) % 1000
        plan = plan_shards(num_docs, chunk_docs, shard_docs)
        nxt = 0
        for s in plan:
            assert s["doc_start"] == nxt
            assert s["doc_start"] % chunk_docs == 0
            assert s["doc_start"] == s["chunk_start"] * chunk_docs
            nxt = s["doc_end"]
        assert nxt == num_docs
        assert plan == plan_shards(num_docs, chunk_docs, shard_docs)


# ---------------------------------------------------------------------------
# The determinism keystone: chunk-order fold of per-chunk accumulators
# ---------------------------------------------------------------------------


class Welford:
    """util::stats::RunningStats — push/merge (Chan et al.)."""

    def __init__(self):
        self.n, self.mean, self.m2 = 0, 0.0, 0.0

    def push(self, x):
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    def merge(self, o):
        if o.n == 0:
            return
        if self.n == 0:
            self.n, self.mean, self.m2 = o.n, o.mean, o.m2
            return
        n1, n2 = float(self.n), float(o.n)
        d = o.mean - self.mean
        n = n1 + n2
        self.mean += d * n2 / n
        self.m2 += o.m2 + d * d * n1 * n2 / n
        self.n += o.n

    def bits(self):
        return (self.n, struct.pack("<d", self.mean), struct.pack("<d", self.m2))


def lcg_values(seed, k):
    out, s = [], seed
    for _ in range(k):
        s = (s * 6364136223846793005 + 1442695040888963407) & MASK
        out.append((s >> 11) / float(1 << 53) * 10.0 - 5.0)
    return out


def chunk_accumulators(chunks, order):
    accs = [None] * len(chunks)
    for i in order:  # computation order is the knob under test
        a = Welford()
        for x in chunks[i]:
            a.push(x)
        accs[i] = a
    return accs


def fold_in_chunk_order(accs):
    m = Welford()
    for a in accs:
        m.merge(a)
    return m


def test_chunk_order_fold_is_invariant_to_worker_schedule():
    # The coordinator merges per-chunk blocks in ascending global chunk
    # index, so which worker computed a block (and when it finished) can
    # never change a bit of the merged accumulator.
    vals = lcg_values(42, 24)
    chunks = [vals[i * 4 : (i + 1) * 4] for i in range(6)]
    reference = fold_in_chunk_order(chunk_accumulators(chunks, range(6)))
    for order in ([5, 4, 3, 2, 1, 0], [2, 0, 4, 1, 5, 3], [3, 5, 0, 2, 4, 1]):
        shuffled = fold_in_chunk_order(chunk_accumulators(chunks, order))
        assert shuffled.bits() == reference.bits()


def test_hierarchical_shard_master_fold_is_not_bitwise():
    # Folding each shard to a master and then merging masters is the
    # "obvious" parallel reduction — and it drifts in the last mantissa
    # bit on this pinned data. This is exactly why run_job merges the
    # per-chunk blocks and never the workers' shard masters.
    vals = lcg_values(42, 24)
    chunks = [vals[i * 4 : (i + 1) * 4] for i in range(6)]
    accs = chunk_accumulators(chunks, range(6))
    reference = fold_in_chunk_order(accs)
    masters = []
    for shard in ([0, 1, 2], [3, 4, 5]):
        m = Welford()
        for i in shard:
            m.merge(accs[i])
        masters.append(m)
    hierarchical = Welford()
    for m in masters:
        hierarchical.merge(m)
    assert hierarchical.n == reference.n
    assert hierarchical.bits() != reference.bits()
