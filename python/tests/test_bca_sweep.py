"""L2 BCA sweep graph vs the pure-numpy Algorithm-1 reference, plus the
solver invariants (objective monotone, PD preserved, PCA limit)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _problem(seed, n, lam_frac=0.4):
    rng = np.random.default_rng(seed)
    sigma = ref.random_psd(rng, n, ridge=0.1)
    lam = lam_frac * float(np.min(np.diag(sigma)))
    beta = 1e-3 / n
    return sigma, lam, beta


@given(n=st.integers(2, 10), seed=st.integers(0, 10_000))
def test_sweep_matches_reference(n, seed):
    sigma, lam, beta = _problem(seed, n)
    x0 = np.eye(n)
    got = model.bca_sweep_np(x0, sigma, lam, beta)
    want = ref.bca_sweep_ref(x0, sigma, lam, beta, model.QP_SWEEPS)
    np.testing.assert_allclose(got, want, atol=1e-10, rtol=1e-8)


@given(n=st.integers(2, 8), seed=st.integers(0, 10_000))
@settings(max_examples=10)
def test_sweeps_monotone_and_pd(n, seed):
    sigma, lam, beta = _problem(seed, n)
    x = np.eye(n)
    prev = ref.barrier_objective_ref(x, sigma, lam, beta)
    for _ in range(3):
        x = model.bca_sweep_np(x, sigma, lam, beta)
        assert np.allclose(x, x.T, atol=1e-12), "sweep must preserve symmetry"
        cur = ref.barrier_objective_ref(x, sigma, lam, beta)
        assert np.isfinite(cur), "iterate left the PD cone"
        # With the fixed QP_SWEEPS inner budget the sub-problem is solved
        # inexactly, so ascent holds only up to the sub-problem residual
        # (the exact-QP monotonicity property is tested on the rust side
        # with a converged inner solver).
        assert cur >= prev - 1e-3 * (1 + abs(prev)), f"objective dropped {prev}→{cur}"
        prev = cur


def test_fixed_point_is_stable():
    # Once converged, another sweep barely moves X.
    sigma, lam, beta = _problem(11, 6)
    x = np.eye(6)
    for _ in range(30):
        x = model.bca_sweep_np(x, sigma, lam, beta)
    x2 = model.bca_sweep_np(x, sigma, lam, beta)
    assert np.abs(x2 - x).max() < 1e-7


def test_lambda_zero_approaches_lambda_max():
    # λ = 0 ⇒ problem (1) is PCA; φ = Tr ΣZ → λ_max(Σ).
    rng = np.random.default_rng(12)
    n = 7
    sigma = ref.random_psd(rng, n, ridge=0.05)
    beta = 1e-5 / n
    x = np.eye(n)
    for _ in range(40):
        x = model.bca_sweep_np(x, sigma, 0.0, beta)
    z = x / np.trace(x)
    phi = float(np.sum(sigma * z))
    lmax = float(np.linalg.eigvalsh(sigma)[-1])
    assert abs(phi - lmax) < 2e-3 * (1 + lmax), f"{phi} vs {lmax}"


def test_zero_padding_is_harmless():
    # Padded features (Σ rows/cols = 0) must not disturb the active block —
    # the XLA engine's fixed-shape strategy depends on this.
    sigma, lam, beta = _problem(13, 5)
    n, pad = 5, 9
    sigma_p = np.zeros((pad, pad))
    sigma_p[:n, :n] = sigma
    x = np.eye(n)
    xp = np.eye(pad)
    xp[n:, n:] = 0.0
    for _ in range(4):
        x = model.bca_sweep_np(x, sigma, lam, beta)
        xp = model.bca_sweep_np(xp, sigma_p, lam, beta)
    # Padded diagonal settles at a tiny positive value; active block agrees
    # up to the O(pad·β/λ) trace perturbation.
    pad_diag = np.diag(xp)[n:]
    assert np.all(pad_diag > 0) and np.all(pad_diag < 1e-2)
    assert np.abs(xp[:n, :n] - x).max() < 5e-2 * (1 + np.abs(x).max())
    # off-diagonal coupling to padding stays zero
    assert np.abs(xp[:n, n:]).max() < 1e-12


def test_tau_solver_matches_ref():
    rng = np.random.default_rng(14)
    for _ in range(50):
        r2 = float(rng.uniform(0, 10))
        beta = float(rng.uniform(1e-8, 0.5))
        c = float(rng.uniform(-10, 10))
        got = float(model.solve_tau(np.float64(r2), np.float64(beta), np.float64(c)))
        want = ref.solve_tau_ref(r2, beta, c)
        assert abs(got - want) < 1e-9 * (1 + abs(want)), (r2, beta, c, got, want)
        # optimality: cubic residual ~ 0
        resid = got**3 + c * got**2 - beta * got - r2
        assert abs(resid) < 1e-7 * (1 + abs(c) ** 2 + r2)
