"""Mirror of the incremental-corpus cross-language contracts.

``rust/src/incr/mod.rs`` chains the corpus identity on every append —
``digest_{i+1} = H("chain:{prev:016x}:{seg:016x}")`` with the crate's
FNV-1a (``checkpoint::corpus_key``) — and persists mid-append Welford
state as ``KIND_APPEND = 3`` LSJS job-state files (same byte layout as
the variance kind, see ``test_fault_mirror``). Both are contracts a
Python operator tool must reproduce to audit or garbage-collect the
digest-keyed caches the Rust pipeline leaves behind.

This mirror reimplements them from the format docs alone and checks:

- FNV-1a and the canonical chain encoding against pinned vectors
  (shared with ``incr::tests::chain_digest_is_deterministic_and_order_
  sensitive``), including order sensitivity and zero-width formatting;
- the KIND_APPEND LSJS image round-trips, and the kind-directed loader
  rejects a variance snapshot at an append path (and vice versa) — the
  exact confusion ``jobstate::load_kind`` exists to prevent;
- the drift gate's arithmetic: the mandatory condition is
  tolerance-independent, the quality condition is a *strict*
  inequality on relative shift (``tol = 0`` fires on any change, an
  unchanged profile never fires).
"""

import struct

MASK = (1 << 64) - 1


def fnv1a(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK
    return h


def corpus_key(identity: str) -> int:
    """checkpoint::corpus_key — FNV-1a over the identity string."""
    return fnv1a(identity.encode())


def chain_digest(prev: int, seg: int) -> int:
    """incr::chain_digest — FNV-1a over the canonical chain encoding."""
    return corpus_key("chain:%016x:%016x" % (prev, seg))


def rotl64(x, k):
    k %= 64
    return ((x << k) | (x >> (64 - k))) & MASK


def xor_fold_checksum(buf):
    acc = 0x9E3779B97F4A7C15
    for i in range(0, len(buf), 8):
        lane = buf[i : i + 8].ljust(8, b"\x00")
        acc ^= rotl64(struct.unpack("<Q", lane)[0], (i // 8) % 63)
    return acc


# ---------------------------------------------------------------------------
# Chained digests
# ---------------------------------------------------------------------------


def test_chain_digest_pinned_vectors():
    # The base identity a synthetic session derives (preset nytimes,
    # 300 docs, 800 vocab, default seed) and one appended segment.
    base = corpus_key("synth:nytimes-synth:300:800:20111212")
    seg = corpus_key("parity-segment")
    assert base == 0xE1F65B5723826D82
    assert seg == 0x664A1CBB21B9B034
    assert chain_digest(base, seg) == 0xA67C6AEE4B56EE10


def test_chain_digest_is_order_sensitive_and_total():
    base = corpus_key("synth:nytimes-synth:300:800:20111212")
    seg = corpus_key("parity-segment")
    # Appending A then B names a different prefix than B then A.
    assert chain_digest(base, seg) != chain_digest(seg, base)
    assert chain_digest(seg, base) == 0x842D4D2653C7FAAC
    # Zero-padding is part of the canonical encoding: small digests
    # still format to 16 hex chars, so encodings never alias.
    assert chain_digest(0, 0) == 0x26D9201420613A5A
    assert chain_digest(0, 0) == corpus_key(
        "chain:0000000000000000:0000000000000000"
    )


def test_chain_digest_composes_per_segment():
    # Three appends = three chain links; every prefix has a distinct
    # digest, which is what keys job state and shard caches.
    d0 = corpus_key("file:docword.nytimes.txt.gz:123456789")
    d1 = chain_digest(d0, corpus_key("day-1"))
    d2 = chain_digest(d1, corpus_key("day-2"))
    d3 = chain_digest(d2, corpus_key("day-3"))
    assert len({d0, d1, d2, d3}) == 4
    # Folding day-2 before day-1 is a different corpus.
    alt = chain_digest(chain_digest(d0, corpus_key("day-2")), corpus_key("day-1"))
    assert alt != d2


# ---------------------------------------------------------------------------
# KIND_APPEND job state
# ---------------------------------------------------------------------------

MAGIC = b"LSJS"
VERSION = 1
KIND_VARIANCE = 1
KIND_REDUCE = 2
KIND_APPEND = 3
HEADER_U64S = 7


def lsjs_bytes(key, kind, chunk_docs, completed_chunks, docs, nnz, triples):
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", VERSION)
    out += struct.pack(
        "<7Q", key, kind, chunk_docs, completed_chunks, docs, nnz, len(triples)
    )
    for n_obs, mean, m2 in triples:
        out += struct.pack("<Qdd", n_obs, mean, m2)
    out += struct.pack("<Q", xor_fold_checksum(out[8:]))
    return bytes(out)


def lsjs_load_kind(buf, key, expected_n, chunk_docs, want_kind):
    """jobstate::load_kind's validation ladder: identical to the
    variance loader, with the kind an explicit parameter."""
    if len(buf) < 8 + 8 * HEADER_U64S + 8 or buf[:4] != MAGIC:
        raise ValueError("bad magic or truncated header")
    (version,) = struct.unpack("<I", buf[4:8])
    if version != VERSION:
        raise ValueError(f"version {version}, want {VERSION}")
    payload = buf[8:-8]
    (stored_sum,) = struct.unpack("<Q", buf[-8:])
    if xor_fold_checksum(payload) != stored_sum:
        raise ValueError("checksum mismatch (corrupt file)")
    hdr = struct.unpack("<7Q", payload[: 8 * HEADER_U64S])
    stored_key, kind, stored_chunk, completed, docs, nnz, n = hdr
    if stored_key != key:
        raise ValueError("corpus key mismatch — foreign job state")
    if kind != want_kind:
        raise ValueError(f"kind {kind}, want {want_kind}")
    if stored_chunk != chunk_docs:
        raise ValueError("chunk size mismatch — stale job state")
    if len(payload) != 8 * HEADER_U64S + 24 * n:
        raise ValueError("payload size mismatch")
    if n != expected_n:
        raise ValueError("dimension mismatch — stale or foreign job state")
    return dict(completed_chunks=completed, docs=docs, nnz=nnz)


def append_state_example():
    chained = chain_digest(
        corpus_key("synth:nytimes-synth:128:600:20111212"), corpus_key("kill-seg")
    )
    triples = [(192, 0.25, 3.5), (192, 0.0, 0.0), (192, 1.5, 12.25)]
    return chained, lsjs_bytes(chained, KIND_APPEND, 64, 3, 192, 411, triples)


def test_kind_append_roundtrip():
    chained, buf = append_state_example()
    st = lsjs_load_kind(buf, chained, 3, 64, KIND_APPEND)
    assert st == dict(completed_chunks=3, docs=192, nnz=411)


def test_kind_mismatch_is_an_identity_mismatch():
    # An append loader must reject a crashed *variance* pass's snapshot
    # sitting at the same digest — same payload shape, different pass.
    chained, _ = append_state_example()
    variance = lsjs_bytes(chained, KIND_VARIANCE, 64, 3, 192, 411, [(192, 0.0, 1.0)])
    try:
        lsjs_load_kind(variance, chained, 1, 64, KIND_APPEND)
        raise AssertionError("variance snapshot adopted by append loader")
    except ValueError as e:
        assert "kind" in str(e)
    # …and symmetrically: the variance pass never resumes append state.
    _, append_buf = append_state_example()
    try:
        lsjs_load_kind(append_buf, chained, 3, 64, KIND_VARIANCE)
        raise AssertionError("append snapshot adopted by variance loader")
    except ValueError as e:
        assert "kind" in str(e)
    assert KIND_APPEND == 3 and KIND_REDUCE == 2 and KIND_VARIANCE == 1


# ---------------------------------------------------------------------------
# Drift gate arithmetic
# ---------------------------------------------------------------------------


def drift_gate(lambda_, kept, kept_variances, merged, tol):
    """incr::drift_gate — mandatory on any eliminated feature crossing
    λ, quality on a *strict* relative-shift exceedance."""
    kept_set = set(kept)
    mandatory = any(
        v > lambda_ for j, v in enumerate(merged) if j not in kept_set
    )
    max_shift = 0.0
    for r, j in enumerate(kept):
        old = kept_variances[r]
        shift = abs(merged[j] - old) / max(old, 1e-12)
        max_shift = max(max_shift, shift)
    return mandatory, max_shift, mandatory or max_shift > tol


def test_drift_gate_mandatory_ignores_tolerance():
    # Feature 2 was eliminated at λ = 1.0; its merged variance rose
    # above λ, so the gate fires at any tolerance.
    kept, kept_var = [0, 1], [4.0, 2.0]
    merged = [4.0, 2.0, 1.5]
    for tol in (0.0, 0.5, 1e9):
        mandatory, _, fired = drift_gate(1.0, kept, kept_var, merged, tol)
        assert mandatory and fired


def test_drift_gate_quality_is_strict():
    kept, kept_var = [0, 1], [4.0, 2.0]
    # Kept feature 0 shifted by exactly 12.5% (0.5/4.0 — exact in
    # binary, so "at tolerance" is testable); eliminated stays below λ.
    merged = [4.5, 2.0, 0.5]
    mandatory, max_shift, fired = drift_gate(1.0, kept, kept_var, merged, 0.125)
    assert not mandatory and max_shift == 0.125
    assert not fired  # strictly-greater: a shift AT tol does not fire
    assert drift_gate(1.0, kept, kept_var, merged, 0.124)[2]
    # tol = 0 fires on any change at all — the forced-parity regime —
    # while a bit-identical profile stays quiet even at tol = 0.
    assert drift_gate(1.0, kept, kept_var, merged, 0.0)[2]
    assert not drift_gate(1.0, kept, kept_var, [4.0, 2.0, 0.5], 0.0)[2]
