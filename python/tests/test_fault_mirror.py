"""Mirror of the fault-tolerance on-disk formats.

``rust/src/jobstate.rs`` persists a resumable pass's partial
accumulators as a ``.lsjs`` file (magic ``LSJS``, ``u32`` version, a
7-``u64`` header, per-feature Welford triples, trailing xor-fold
checksum), and ``rust/src/deadletter.rs`` quarantines malformed corpus
records as fixed-key-order JSONL with a per-record checksum. Both
layouts are cross-language contracts: a Python operator tool must be
able to audit a job-state file or a dead-letter queue written by the
Rust pipeline.

This mirror reimplements both byte layouts from the format docs alone
and checks:

- the xor-fold checksum fold (golden-ratio seed, per-lane rotation)
  against pinned vectors shared with the Rust unit tests;
- LSJS roundtrip plus every rejection the Rust loader enforces (bad
  magic, wrong version, flipped payload byte, truncation, foreign key,
  stale chunk size, dimension mismatch);
- the dead-letter record bytes (fixed key order, escaping, crc-last)
  against the same pinned literals as ``deadletter::tests``.
"""

import struct

MASK = (1 << 64) - 1


def rotl64(x, k):
    k %= 64
    return ((x << k) | (x >> (64 - k))) & MASK


def xor_fold_checksum(buf):
    """util::xor_fold_checksum — 8-byte LE lanes, zero-padded tail,
    lane ``i`` rotated left by ``i % 63`` before folding."""
    acc = 0x9E3779B97F4A7C15
    for i in range(0, len(buf), 8):
        lane = buf[i : i + 8].ljust(8, b"\x00")
        acc ^= rotl64(struct.unpack("<Q", lane)[0], (i // 8) % 63)
    return acc


# ---------------------------------------------------------------------------
# LSJS job-state files
# ---------------------------------------------------------------------------

MAGIC = b"LSJS"
VERSION = 1
KIND_VARIANCE = 1
HEADER_U64S = 7


def lsjs_bytes(key, kind, chunk_docs, completed_chunks, docs, nnz, triples):
    """jobstate::save's byte image: magic, version, header, triples,
    trailing checksum of everything after the 8 framing bytes."""
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", VERSION)
    out += struct.pack(
        "<7Q", key, kind, chunk_docs, completed_chunks, docs, nnz, len(triples)
    )
    for n_obs, mean, m2 in triples:
        out += struct.pack("<Qdd", n_obs, mean, m2)
    out += struct.pack("<Q", xor_fold_checksum(out[8:]))
    return bytes(out)


def lsjs_load(buf, key, expected_n, chunk_docs):
    """jobstate::load's validation ladder, raising ValueError with the
    same reason vocabulary where Rust rejects."""
    if len(buf) < 8 + 8 * HEADER_U64S + 8 or buf[:4] != MAGIC:
        raise ValueError("bad magic or truncated header")
    (version,) = struct.unpack("<I", buf[4:8])
    if version != VERSION:
        raise ValueError(f"version {version}, want {VERSION}")
    payload = buf[8:-8]
    (stored_sum,) = struct.unpack("<Q", buf[-8:])
    if xor_fold_checksum(payload) != stored_sum:
        raise ValueError("checksum mismatch (corrupt file)")
    hdr = struct.unpack("<7Q", payload[: 8 * HEADER_U64S])
    stored_key, kind, stored_chunk, completed, docs, nnz, n = hdr
    if stored_key != key:
        raise ValueError("corpus key mismatch — foreign job state")
    if kind != KIND_VARIANCE:
        raise ValueError(f"unknown kind {kind}")
    if stored_chunk != chunk_docs:
        raise ValueError("chunk size mismatch — stale job state")
    if len(payload) != 8 * HEADER_U64S + 24 * n:
        raise ValueError("payload size mismatch")
    if n != expected_n:
        raise ValueError("dimension mismatch — stale or foreign job state")
    triples = [
        struct.unpack("<Qdd", payload[8 * HEADER_U64S + 24 * i :][:24])
        for i in range(n)
    ]
    return dict(
        key=key,
        kind=kind,
        chunk_docs=chunk_docs,
        completed_chunks=completed,
        docs=docs,
        nnz=nnz,
        triples=triples,
    )


EXAMPLE = dict(
    key=0x1122334455667788,
    kind=KIND_VARIANCE,
    chunk_docs=64,
    completed_chunks=3,
    docs=192,
    nnz=1000,
    triples=[(5, 1.5, 0.25), (7, -2.0, 3.5)],
)

# The same example is pinned byte-for-byte on the Rust side
# (jobstate::tests::file_bytes_are_stable) — the trailing checksum of
# its payload must come out to this exact value in both languages.
EXAMPLE_CHECKSUM = 0x17154AFD2A2C67C7


def example_bytes(**override):
    kw = dict(EXAMPLE)
    kw.update(override)
    return lsjs_bytes(
        kw["key"],
        kw["kind"],
        kw["chunk_docs"],
        kw["completed_chunks"],
        kw["docs"],
        kw["nnz"],
        kw["triples"],
    )


def test_lsjs_pinned_checksum():
    buf = example_bytes()
    assert struct.unpack("<Q", buf[-8:])[0] == EXAMPLE_CHECKSUM
    assert len(buf) == 8 + 8 * HEADER_U64S + 24 * 2 + 8


def test_lsjs_roundtrip():
    st = lsjs_load(example_bytes(), EXAMPLE["key"], 2, 64)
    assert st["completed_chunks"] == 3
    assert st["docs"] == 192 and st["nnz"] == 1000
    assert st["triples"] == EXAMPLE["triples"]


def test_lsjs_rejects_corruption_and_staleness():
    import pytest

    good = example_bytes()
    key = EXAMPLE["key"]

    with pytest.raises(ValueError, match="bad magic"):
        lsjs_load(b"LSPV" + good[4:], key, 2, 64)
    with pytest.raises(ValueError, match="bad magic"):
        lsjs_load(good[: 8 + 8 * HEADER_U64S], key, 2, 64)  # truncated
    with pytest.raises(ValueError, match="version"):
        lsjs_load(good[:4] + struct.pack("<I", 9) + good[8:], key, 2, 64)

    flipped = bytearray(good)
    flipped[20] ^= 0x01  # a payload byte
    with pytest.raises(ValueError, match="checksum mismatch"):
        lsjs_load(bytes(flipped), key, 2, 64)

    # identity mismatches are detected *after* the checksum verifies:
    # the file is intact, it just belongs to another run
    with pytest.raises(ValueError, match="foreign job state"):
        lsjs_load(good, key ^ 0xDEAD, 2, 64)
    with pytest.raises(ValueError, match="stale job state"):
        lsjs_load(good, key, 2, 32)
    with pytest.raises(ValueError, match="dimension mismatch"):
        lsjs_load(good, key, 3, 64)
    with pytest.raises(ValueError, match="unknown kind"):
        lsjs_load(example_bytes(kind=2), key, 2, 64)


def test_lsjs_checksum_covers_every_payload_byte():
    good = example_bytes()
    import pytest

    for off in range(8, len(good) - 8):
        flipped = bytearray(good)
        flipped[off] ^= 0x80
        with pytest.raises(ValueError):
            lsjs_load(bytes(flipped), EXAMPLE["key"], 2, 64)


# ---------------------------------------------------------------------------
# dead-letter JSONL records
# ---------------------------------------------------------------------------


def escape_json(s):
    """deadletter::escape_json — backslash, quote, and C0 controls as
    ``\\u00XX``; everything else verbatim."""
    out = []
    for c in s:
        if c == "\\":
            out.append("\\\\")
        elif c == '"':
            out.append('\\"')
        elif ord(c) < 0x20:
            out.append("\\u%04x" % ord(c))
        else:
            out.append(c)
    return "".join(out)


def format_record(offset, reason, detail, line):
    """deadletter::format_record — crc over the record minus its own
    ``crc`` field, spliced in before the closing brace."""
    prefix = '{"offset":%d,"reason":"%s","detail":"%s","line":"%s"}' % (
        offset,
        reason,
        escape_json(detail),
        escape_json(line),
    )
    crc = xor_fold_checksum(prefix.encode())
    return '%s,"crc":"%016x"}' % (prefix[:-1], crc)


# Shared with deadletter::tests::record_bytes_are_stable: same inputs,
# same full line, down to the checksum hex.
PINNED_RECORD = (
    '{"offset":17,"reason":"word-out-of-range",'
    '"detail":"wordID 9 exceeds W=5","line":"3 9 1",'
    '"crc":"7e673c33f156083c"}'
)


def test_dlq_pinned_record_bytes():
    got = format_record(17, "word-out-of-range", "wordID 9 exceeds W=5", "3 9 1")
    assert got == PINNED_RECORD


def test_dlq_escaping():
    rec = format_record(1, "bad-doc-id", 'a"b\\c', "tab\there")
    assert '"detail":"a\\"b\\\\c"' in rec
    assert '"line":"tab\\u0009here"' in rec
    # the escaped form is what the checksum covers — recomputing from
    # the parsed record must reproduce it
    import json

    parsed = json.loads(rec)
    assert parsed["detail"] == 'a"b\\c'
    assert parsed["line"] == "tab\there"
    again = format_record(
        parsed["offset"], parsed["reason"], parsed["detail"], parsed["line"]
    )
    assert again == rec


def test_dlq_crc_detects_tampering():
    import json

    rec = format_record(3, "bad-count", "bad count in line '1 2 x'", "1 2 x")
    tampered = rec.replace("1 2 x", "9 2 x")
    parsed = json.loads(tampered)
    prefix = '{"offset":%d,"reason":"%s","detail":"%s","line":"%s"}' % (
        parsed["offset"],
        parsed["reason"],
        escape_json(parsed["detail"]),
        escape_json(parsed["line"]),
    )
    assert "%016x" % xor_fold_checksum(prefix.encode()) != parsed["crc"]


def test_dlq_reason_vocabulary_is_closed():
    # BadRecordReason::as_str — any new reason must be added to both
    # sides (the Rust roundtrip test and this list) or `lsspca dlq`
    # tooling written against this schema would misclassify it.
    reasons = [
        "bad-doc-id",
        "bad-word-id",
        "bad-count",
        "zero-id",
        "word-out-of-range",
        "non-monotonic-doc",
        "gzip-crc",
    ]
    for r in reasons:
        rec = format_record(1, r, "d", "l")
        assert '"reason":"%s"' % r in rec
