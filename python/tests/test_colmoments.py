"""Col-moments Pallas kernel vs numpy, plus the variance identity."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.colmoments import col_moments, TILE


@given(mi=st.integers(1, 64), ni=st.integers(1, 4), seed=st.integers(0, 1000))
@settings(max_examples=10)
def test_matches_numpy(mi, ni, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((mi, ni * TILE))
    s, ss = col_moments(a)
    np.testing.assert_allclose(np.asarray(s), a.sum(axis=0), atol=1e-9)
    np.testing.assert_allclose(np.asarray(ss), (a * a).sum(axis=0), atol=1e-9)


def test_variance_identity():
    rng = np.random.default_rng(7)
    m = 500
    a = rng.poisson(2.0, size=(m, TILE)).astype(np.float64)
    s, ss = col_moments(a)
    var = np.asarray(ss) / m - (np.asarray(s) / m) ** 2
    np.testing.assert_allclose(var, a.var(axis=0), atol=1e-9)


def test_block_accumulation_equals_whole():
    # Two half-blocks summed == one pass (the streaming merge identity).
    rng = np.random.default_rng(8)
    a = rng.standard_normal((200, TILE))
    s1, ss1 = col_moments(a[:90])
    s2, ss2 = col_moments(a[90:])
    s, ss = col_moments(a)
    np.testing.assert_allclose(np.asarray(s1) + np.asarray(s2), np.asarray(s), atol=1e-9)
    np.testing.assert_allclose(np.asarray(ss1) + np.asarray(ss2), np.asarray(ss), atol=1e-9)
