"""Additional L2-graph properties: power iteration vs oracle, the masked
formulation's exactness, and the column update's analytic identities."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.boxqp import boxqp


@given(n=st.integers(2, 16), seed=st.integers(0, 10_000))
@settings(max_examples=15)
def test_power_iter_matches_oracle_and_numpy(n, seed):
    rng = np.random.default_rng(seed)
    sigma = ref.random_psd(rng, n)
    v0 = rng.standard_normal(n)
    v, val = model.power_iter(np.asarray(sigma), np.asarray(v0))
    v_ref, val_ref = ref.power_iter_ref(sigma, v0, model.POWER_ITERS)
    np.testing.assert_allclose(np.asarray(v), v_ref, atol=1e-10)
    assert abs(float(val) - val_ref) < 1e-10 * (1 + abs(val_ref))
    # and both approximate the true λ_max
    lmax = float(np.linalg.eigvalsh(sigma)[-1])
    assert abs(float(val) - lmax) < 1e-4 * (1 + lmax)


@given(n=st.integers(2, 10), seed=st.integers(0, 10_000))
@settings(max_examples=15)
def test_masked_qp_equals_submatrix_qp(n, seed):
    """The masked full-size QP (r[j]=0, s[j]=0, row/col j zeroed) must equal
    the explicit (n−1)-submatrix QP — the identity the fixed-shape AOT
    strategy rests on."""
    rng = np.random.default_rng(seed)
    y = ref.random_psd(rng, n)
    j = int(rng.integers(n))
    lam = 0.6
    s_full = rng.standard_normal(n)
    # masked
    ym = y.copy()
    ym[j, :] = 0.0
    ym[:, j] = 0.0
    sm = s_full.copy()
    sm[j] = 0.0
    r = np.full(n, lam)
    r[j] = 0.0
    u_m, w_m = boxqp(ym, sm, r, nsweeps=64)
    # explicit submatrix
    keep = [i for i in range(n) if i != j]
    ysub = y[np.ix_(keep, keep)]
    ssub = s_full[keep]
    u_s, w_s = boxqp(ysub, ssub, np.full(n - 1, lam), nsweeps=64)
    np.testing.assert_allclose(np.asarray(u_m)[keep], np.asarray(u_s), atol=1e-9)
    r2_m = float(np.asarray(u_m) @ np.asarray(w_m))
    r2_s = float(np.asarray(u_s) @ np.asarray(w_s))
    assert abs(r2_m - r2_s) < 1e-8 * (1 + abs(r2_s))


def test_column_update_diagonal_identity():
    """After a column update, x_jj = β/τ + R²/τ² (paper Eq. 8 + τ-optimality):
    the barrier keeps the diagonal strictly positive."""
    rng = np.random.default_rng(21)
    n = 7
    sigma = ref.random_psd(rng, n)
    lam = 0.3 * float(np.min(np.diag(sigma)))
    beta = 1e-3 / n
    x = np.eye(n)
    x2 = model.bca_sweep_np(x, sigma, lam, beta)
    assert np.all(np.diag(x2) > 0.0)
    # replay column j = n-1 by hand to check the identity
    xj = ref.bca_sweep_ref(x, sigma, lam, beta, model.QP_SWEEPS)
    assert np.all(np.diag(xj) > 0.0)


def test_sweep_deterministic():
    rng = np.random.default_rng(22)
    sigma = ref.random_psd(rng, 6)
    a = model.bca_sweep_np(np.eye(6), sigma, 0.1, 1e-4)
    b = model.bca_sweep_np(np.eye(6), sigma, 0.1, 1e-4)
    np.testing.assert_array_equal(a, b)


def test_gram_block_entry_point_tuple():
    rng = np.random.default_rng(23)
    a = rng.standard_normal((256, 512))
    (g,) = model.gram_block(a)
    np.testing.assert_allclose(np.asarray(g), a.T @ a, atol=1e-8)
