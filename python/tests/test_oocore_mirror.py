"""Mirror of the out-of-core covariance backend's bitwise claims.

``rust/src/cov_disk.rs`` asserts that streaming the reduced term matrix
as *column-range shards* reproduces the in-memory ``GramCov`` kernels
bit for bit, because every kernel replays the identical floating-point
summation order. This mirror implements both sides in pure Python
(IEEE-754 doubles, same add/mul semantics as Rust ``f64``) and compares
results **by bit pattern** (``struct.pack``), not by ``==`` — the claim
is bitwise identity, and ``==`` would hide a ``-0.0`` / ``0.0`` swap.

Mirrored kernels (names match the Rust side):

- ``compute_row``  — in-memory doc-scatter vs shard sorted-merge dots;
- ``matvec``       — in-memory CSR row-major ``ax`` + row-major scatter
  vs shard column sweep + per-column gather (with the ``ax[d] == 0``
  skip both sides);
- ``quad_form``    — shared ``ax`` then sum of squares;
- shard packing    — the greedy fixed-byte-budget column split tiles
  the columns exactly once, in order.
"""

import random
import struct

# ---------------------------------------------------------------------------
# fixtures: a doc-id-sorted, column-sorted reduced CSR and its CSC
# ---------------------------------------------------------------------------


def build_csr(rng, rows, cols, density=0.35):
    """Rows sorted by doc id; entries within a row sorted by column —
    the canonical layout ``ReducedDocsAccum::finalize`` emits."""
    csr = []
    for _ in range(rows):
        row = [(c, float(rng.randint(1, 5))) for c in range(cols) if rng.random() < density]
        csr.append(row)  # already ascending in c by construction
    return csr


def to_csc(csr, cols):
    """Counting-sort transpose: ascending doc ids within each column."""
    csc = [[] for _ in range(cols)]
    for r, row in enumerate(csr):
        for c, v in row:
            csc[c].append((r, v))
    return csc


def mean_of(csr, cols, m):
    """GramCov::new's fold: row-major accumulation, then /m."""
    sums = [0.0] * cols
    for row in csr:
        for c, v in row:
            sums[c] += v
    return [s / m for s in sums]


def diag_of(csc, mean, cols, m):
    """col_moments' per-column sum of squares, then centering."""
    out = []
    for c in range(cols):
        ss = 0.0
        for _, v in csc[c]:
            ss += v * v
        out.append(ss / m - mean[c] * mean[c])
    return out


def plan_shards(col_nnz, shard_bytes):
    """Greedy fixed-byte-budget split (shardcache::plan_shards)."""

    def payload(ncols, nnz):
        return 8 * (ncols + 1) + 12 * nnz

    ranges, start = [], 0
    while start < len(col_nnz):
        end, nnz = start + 1, col_nnz[start]
        while end < len(col_nnz):
            nxt = nnz + col_nnz[end]
            if payload(end + 1 - start, nxt) > shard_bytes:
                break
            nnz = nxt
            end += 1
        ranges.append((start, end - start))
        start = end
    return ranges or [(0, 0)]


def bits(x):
    return struct.pack("<d", x)


def bits_vec(xs):
    return [bits(x) for x in xs]


# ---------------------------------------------------------------------------
# the two implementations of each kernel
# ---------------------------------------------------------------------------


def row_inmem(csr, csc, mean, m, j, cols):
    """GramCov::compute_row: scatter over docs containing j."""
    out = [0.0] * cols
    for d, aj in csc[j]:
        for k, ak in csr[d]:
            out[k] += aj * ak
    inv_m = 1.0 / m
    mu_j = mean[j]
    return [out[k] * inv_m - mu_j * mean[k] for k in range(cols)]


def row_disk(csc, shards, mean, m, j, cols):
    """DiskGramCov::compute_row: sorted-merge dot per shard column."""
    colj = csc[j]
    inv_m = 1.0 / m
    mu_j = mean[j]
    out = [0.0] * cols
    for start, ncols in shards:
        for c in range(start, start + ncols):
            colk = csc[c]
            acc, a, b = 0.0, 0, 0
            while a < len(colj) and b < len(colk):
                da, va = colj[a]
                dk, vk = colk[b]
                if da < dk:
                    a += 1
                elif da > dk:
                    b += 1
                else:
                    acc += va * vk
                    a += 1
                    b += 1
            out[c] = acc * inv_m - mu_j * mean[c]
    return out


def matvec_inmem(csr, csc_unused, mean, m, x, rows, cols):
    """GramCov::matvec: per-row dot (ax), row-major scatter (y), center."""
    ax = [0.0] * rows
    for r, row in enumerate(csr):
        acc = 0.0
        for c, v in row:
            acc += v * x[c]
        ax[r] = acc
    y = [0.0] * cols
    for r, row in enumerate(csr):
        a = ax[r]
        if a == 0.0:
            continue
        for c, v in row:
            y[c] += v * a
    inv_m = 1.0 / m
    mux = dot_unrolled(mean, x)
    return [y[c] * inv_m - mean[c] * mux for c in range(cols)], ax


def matvec_disk(csc, shards, mean, m, x, rows, cols):
    """DiskGramCov::matvec: shard column sweep for ax (ascending column
    order == the sorted CSR row order), per-column gather for y."""
    ax = [0.0] * rows
    for start, ncols in shards:
        for c in range(start, start + ncols):
            xc = x[c]
            for d, v in csc[c]:
                ax[d] += v * xc
    y = [0.0] * cols
    for start, ncols in shards:
        for c in range(start, start + ncols):
            acc = 0.0
            for d, v in csc[c]:
                a = ax[d]
                if a == 0.0:
                    continue
                acc += v * a
            y[c] = acc
    inv_m = 1.0 / m
    mux = dot_unrolled(mean, x)
    return [y[c] * inv_m - mean[c] * mux for c in range(cols)], ax


def dot_unrolled(a, b):
    """linalg::vec::dot — 4-way unrolled with four accumulators, tail
    folded into the combined sum (same association as the Rust kernel)."""
    n = len(a)
    chunks = n // 4
    s0 = s1 = s2 = s3 = 0.0
    for k in range(chunks):
        i = 4 * k
        s0 += a[i] * b[i]
        s1 += a[i + 1] * b[i + 1]
        s2 += a[i + 2] * b[i + 2]
        s3 += a[i + 3] * b[i + 3]
    s = (s0 + s1) + (s2 + s3)
    for i in range(4 * chunks, n):
        s += a[i] * b[i]
    return s


# ---------------------------------------------------------------------------
# tests
# ---------------------------------------------------------------------------


def cases(seed, trials):
    rng = random.Random(seed)
    for _ in range(trials):
        rows = rng.randint(3, 60)
        cols = rng.randint(2, 24)
        m = rows + rng.randint(0, 3)  # empty docs count toward m
        csr = build_csr(rng, rows, cols)
        csc = to_csc(csr, cols)
        mean = mean_of(csr, cols, m)
        shard_bytes = rng.choice([64, 200, 1 << 20])
        shards = plan_shards([len(csc[c]) for c in range(cols)], shard_bytes)
        yield rng, rows, cols, m, csr, csc, mean, shards


def test_shard_plan_tiles_columns():
    rng = random.Random(7)
    for _ in range(50):
        cols = rng.randint(1, 40)
        nnz = [rng.randint(0, 30) for _ in range(cols)]
        for budget in (1, 100, 400, 1 << 20):
            ranges = plan_shards(nnz, budget)
            expect = 0
            for start, ncols in ranges:
                assert start == expect and ncols >= 1
                expect += ncols
            assert expect == cols


def test_row_gather_bitwise():
    for rng, rows, cols, m, csr, csc, mean, shards in cases(1, 40):
        for j in range(cols):
            a = row_inmem(csr, csc, mean, m, j, cols)
            b = row_disk(csc, shards, mean, m, j, cols)
            assert bits_vec(a) == bits_vec(b), f"row {j} differs"


def test_matvec_bitwise():
    for rng, rows, cols, m, csr, csc, mean, shards in cases(2, 40):
        x = [rng.uniform(-1, 1) for _ in range(cols)]
        ya, axa = matvec_inmem(csr, csc, mean, m, x, rows, cols)
        yb, axb = matvec_disk(csc, shards, mean, m, x, rows, cols)
        assert bits_vec(axa) == bits_vec(axb), "ax (A·x) differs"
        assert bits_vec(ya) == bits_vec(yb), "matvec differs"


def test_quad_form_bitwise():
    for rng, rows, cols, m, csr, csc, mean, shards in cases(3, 40):
        x = [rng.uniform(-1, 1) for _ in range(cols)]
        _, axa = matvec_inmem(csr, csc, mean, m, x, rows, cols)
        _, axb = matvec_disk(csc, shards, mean, m, x, rows, cols)
        qa = sum_sq(axa) / m - dot_unrolled(mean, x) ** 2
        qb = sum_sq(axb) / m - dot_unrolled(mean, x) ** 2
        assert bits(qa) == bits(qb)


def sum_sq(xs):
    acc = 0.0
    for v in xs:
        acc += v * v
    return acc


def test_diag_matches_row_gather_diagonal_closely():
    # The diagonal is precomputed from col_moments (a different but
    # mathematically equal fold); it need only match the gathered row's
    # diagonal entry to rounding, and must be identical between the two
    # backends by construction (both read the same manifest value).
    for rng, rows, cols, m, csr, csc, mean, shards in cases(4, 20):
        diag = diag_of(csc, mean, cols, m)
        for j in range(cols):
            row = row_inmem(csr, csc, mean, m, j, cols)
            assert abs(diag[j] - row[j]) <= 1e-12 * (1.0 + abs(diag[j]))
