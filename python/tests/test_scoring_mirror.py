"""Pure-python mirror of the serving subsystem's logic (rust/src/model.rs,
rust/src/score/scorer.rs, and the `lsspca bench --compare` gate rule).

The Rust side has no interpreter in the authoring environment, so the
binary artifact format, the sparse projection arithmetic and the gate
comparison are mirrored here statement-for-statement and cross-checked
against dense numpy references. Runs under pytest in CI and standalone
via `python3 python/tests/test_scoring_mirror.py`.
"""

import io
import struct

import numpy as np

MAGIC = b"LSPM"
VERSION = 1
MASK64 = (1 << 64) - 1


# --- checksum (mirror of model.rs::checksum / checkpoint.rs) ---------------

def rotl64(x, k):
    k %= 64
    return ((x << k) | (x >> (64 - k))) & MASK64


def checksum(buf: bytes) -> int:
    acc = 0x9E3779B97F4A7C15
    for i in range(0, len(buf), 8):
        chunk = buf[i : i + 8]
        lane = int.from_bytes(chunk + b"\0" * (8 - len(chunk)), "little")
        acc ^= rotl64(lane, (i // 8) % 63)
    return acc


def fnv1a(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK64
    return h


def vocab_hash(words) -> int:
    return fnv1a(b"".join(w.encode() + b"\n" for w in words))


# --- artifact encode/decode (mirror of Model::to_bytes / from_bytes) --------

def _put_str(out, s):
    b = s.encode()
    out.write(struct.pack("<Q", len(b)))
    out.write(b)


def model_to_bytes(m: dict) -> bytes:
    p = io.BytesIO()
    _put_str(p, m["corpus_name"])
    p.write(struct.pack("<QQQQd", m["num_docs"], m["n_features"], m["vocab_hash"],
                        m["seed"], m["elim_lambda"]))
    p.write(struct.pack("<Q", len(m["kept"])))
    for k in m["kept"]:
        p.write(struct.pack("<Q", k))
    for v in m["kept_means"]:
        p.write(struct.pack("<d", v))
    for v in m["kept_stds"]:
        p.write(struct.pack("<d", v))
    for w in m["kept_words"]:
        _put_str(p, w)
    p.write(struct.pack("<Q", len(m["pcs"])))
    for pc in m["pcs"]:
        p.write(struct.pack("<ddd", pc["lambda"], pc["phi"], pc["explained_variance"]))
        p.write(struct.pack("<Q", len(pc["loadings"])))
        for idx, w in pc["loadings"]:
            p.write(struct.pack("<Qd", idx, w))
    payload = p.getvalue()
    return MAGIC + struct.pack("<I", VERSION) + payload + struct.pack("<Q", checksum(payload))


class Corrupt(Exception):
    pass


def model_from_bytes(buf: bytes) -> dict:
    if len(buf) < 4 + 4 + 8 or buf[:4] != MAGIC:
        raise Corrupt("bad magic or truncated header")
    (version,) = struct.unpack("<I", buf[4:8])
    if version != VERSION:
        raise Corrupt(f"version {version}")
    payload, stored = buf[8:-8], struct.unpack("<Q", buf[-8:])[0]
    if checksum(payload) != stored:
        raise Corrupt("checksum mismatch")
    pos = [0]

    def take(n):
        if pos[0] + n > len(payload):
            raise Corrupt("truncated payload")
        out = payload[pos[0] : pos[0] + n]
        pos[0] += n
        return out

    def u64():
        return struct.unpack("<Q", take(8))[0]

    def f64():
        return struct.unpack("<d", take(8))[0]

    def s():
        ln = u64()
        if ln > len(payload):
            raise Corrupt("implausible length")
        return take(ln).decode()

    m = {"corpus_name": s(), "num_docs": u64(), "n_features": u64(),
         "vocab_hash": u64(), "seed": u64(), "elim_lambda": f64()}
    nk = u64()
    if nk > len(payload):
        raise Corrupt("implausible kept count")
    m["kept"] = [u64() for _ in range(nk)]
    m["kept_means"] = [f64() for _ in range(nk)]
    m["kept_stds"] = [f64() for _ in range(nk)]
    m["kept_words"] = [s() for _ in range(nk)]
    npcs = u64()
    if npcs > len(payload):
        raise Corrupt("implausible pc count")
    m["pcs"] = []
    for _ in range(npcs):
        pc = {"lambda": f64(), "phi": f64(), "explained_variance": f64()}
        card = u64()
        if card > len(payload):
            raise Corrupt("implausible loading count")
        pc["loadings"] = [(u64(), f64()) for _ in range(card)]
        m["pcs"].append(pc)
    if pos[0] != len(payload):
        raise Corrupt("trailing bytes")
    return m


# --- scorer (mirror of score/scorer.rs) -------------------------------------

class Scorer:
    def __init__(self, model, center=True, normalize=False):
        self.k = len(model["pcs"])
        self.n = model["n_features"]
        kept_pos = {orig: p for p, orig in enumerate(model["kept"])}
        self.index = {}
        offsets = [0.0] * self.k
        for pc_idx, pc in enumerate(model["pcs"]):
            for orig, loading in pc["loadings"]:
                p = kept_pos[orig]
                if normalize:
                    s = model["kept_stds"][p]
                    weight = loading / s if s > 0.0 else 0.0
                else:
                    weight = loading
                if center:
                    offsets[pc_idx] += weight * model["kept_means"][p]
                self.index.setdefault(orig, []).append((pc_idx, weight))
        # stored pre-negated; zero sums normalize to +0.0 (no "-0" output)
        self.neg_offsets = [0.0 if o == 0.0 else -o for o in offsets]

    def score(self, words):
        out = list(self.neg_offsets)
        for w, c in words:
            if w >= self.n:
                raise ValueError(f"word id {w} out of range")
            for pc, weight in self.index.get(w, ()):
                out[pc] += weight * c
        return out

    @staticmethod
    def top_pcs(scores, top):
        order = sorted(range(len(scores)), key=lambda i: (-scores[i], i))
        return order[: max(1, min(top, len(scores)))]


# --- bench gate rule (mirror of main.rs::bench_compare_gate) ----------------

def gate_ok(cur, base, max_regress):
    return cur / base <= 1.0 + max_regress


# --- fixtures ----------------------------------------------------------------

def random_model(rng, n=400, nk=30, k=4):
    kept = sorted(rng.choice(n, size=nk, replace=False).tolist())
    pcs = []
    for _ in range(k):
        card = int(rng.integers(2, 7))
        sup = rng.choice(nk, size=card, replace=False)
        loadings = [(kept[int(p)], float(rng.normal())) for p in sup]
        loadings.sort(key=lambda t: -abs(t[1]))
        pcs.append({"lambda": float(rng.uniform(0.1, 2)), "phi": float(rng.uniform(0, 5)),
                    "explained_variance": float(rng.uniform(0, 5)), "loadings": loadings})
    return {
        "corpus_name": "mirror", "num_docs": 999, "n_features": n,
        "vocab_hash": vocab_hash(f"w{i}" for i in range(n)), "seed": 7,
        "elim_lambda": 0.5, "kept": kept,
        "kept_means": [float(rng.normal()) for _ in range(nk)],
        "kept_stds": [float(rng.uniform(0.2, 3)) for _ in range(nk)],
        "kept_words": [f"w{kept[i]}" for i in range(nk)],
        "pcs": pcs,
    }


def random_doc(rng, n, nnz):
    ids = sorted(rng.choice(n, size=nnz, replace=False).tolist())
    return [(i, float(rng.integers(1, 9))) for i in ids]


# --- tests -------------------------------------------------------------------

def test_artifact_roundtrip_bitwise():
    rng = np.random.default_rng(1)
    for trial in range(20):
        m = random_model(rng)
        got = model_from_bytes(model_to_bytes(m))
        assert got == m, f"trial {trial}"


def test_artifact_corruption_always_detected():
    rng = np.random.default_rng(2)
    m = random_model(rng)
    good = model_to_bytes(m)
    for at in rng.integers(0, len(good), size=60):
        bad = bytearray(good)
        bad[int(at)] ^= 1 << int(rng.integers(0, 8))
        try:
            model_from_bytes(bytes(bad))
            raise AssertionError(f"flip at {at} accepted")
        except Corrupt:
            pass
    for cut in rng.integers(0, len(good) - 1, size=30):
        try:
            model_from_bytes(good[: int(cut)])
            raise AssertionError(f"truncation at {cut} accepted")
        except Corrupt:
            pass


def test_scorer_matches_dense_projection():
    """Sparse hash-accumulation == dense W @ (x − μ) for every option combo."""
    rng = np.random.default_rng(3)
    for trial in range(30):
        m = random_model(rng)
        n, k = m["n_features"], len(m["pcs"])
        mu = np.zeros(n)
        sd = np.ones(n)
        for p, orig in enumerate(m["kept"]):
            mu[orig] = m["kept_means"][p]
            sd[orig] = m["kept_stds"][p]
        doc = random_doc(rng, n, int(rng.integers(1, 40)))
        x = np.zeros(n)
        for i, c in doc:
            x[i] = c
        for center in (False, True):
            for normalize in (False, True):
                W = np.zeros((k, n))
                for pc_idx, pc in enumerate(m["pcs"]):
                    for orig, loading in pc["loadings"]:
                        W[pc_idx, orig] = loading / sd[orig] if normalize else loading
                want = W @ (x - mu) if center else W @ x
                got = Scorer(m, center=center, normalize=normalize).score(doc)
                np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12), trial


def test_scorer_zero_std_guard():
    rng = np.random.default_rng(4)
    m = random_model(rng)
    m["kept_stds"] = [0.0] * len(m["kept_stds"])
    got = Scorer(m, center=True, normalize=True).score(random_doc(rng, m["n_features"], 20))
    assert all(s == 0.0 for s in got)


def test_scorer_deterministic_bitwise():
    rng = np.random.default_rng(5)
    m = random_model(rng)
    doc = random_doc(rng, m["n_features"], 25)
    s = Scorer(m, center=True, normalize=True)
    a, b = s.score(doc), s.score(doc)
    assert [struct.pack("<d", x) for x in a] == [struct.pack("<d", x) for x in b]


def test_top_pcs_tie_rule():
    assert Scorer.top_pcs([1.0, 3.0, 3.0, 2.0], 2) == [1, 2]
    assert Scorer.top_pcs([0.0, 0.0], 1) == [0]
    assert Scorer.top_pcs([1.0, 2.0], 5) == [1, 0]
    assert Scorer.top_pcs([5.0], 0) == [0]  # clamped to 1


def test_mean_document_scores_zero_when_centered():
    rng = np.random.default_rng(6)
    m = random_model(rng)
    doc = [(orig, m["kept_means"][p]) for p, orig in enumerate(m["kept"])]
    for sc in Scorer(m, center=True).score(doc):
        assert abs(sc) < 1e-12


def test_uncentered_scores_are_positive_zero():
    rng = np.random.default_rng(7)
    m = random_model(rng)
    for sc in Scorer(m, center=False).score([]):
        assert struct.pack("<d", sc) == struct.pack("<d", 0.0)


def test_gate_rule():
    assert gate_ok(1.0, 1.0, 0.25)
    assert gate_ok(1.24, 1.0, 0.25)
    assert not gate_ok(1.26, 1.0, 0.25)
    assert gate_ok(0.1, 1.0, 0.25)  # faster is always fine
    assert not gate_ok(2.0, 1.0, 0.0)


def test_fnv_vectors():
    # Known FNV-1a 64-bit vectors pin the hash the Rust side implements.
    assert fnv1a(b"") == 0xCBF29CE484222325
    assert fnv1a(b"a") == 0xAF63DC4C8601EC8C
    assert fnv1a(b"foobar") == 0x85944171F73967E8
    assert vocab_hash(["alpha", "beta"]) != vocab_hash(["alphabeta"])


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"ok {fn.__name__}")
    print(f"all {len(fns)} mirror tests passed")
