"""L1 Pallas box-QP kernel vs the pure-numpy oracle (the CORE correctness
signal for the kernel layer) + KKT optimality checks."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.boxqp import boxqp


def kkt_residual(y, s, lam, u):
    """Worst KKT violation for uniform box radius lam (cf. rust solver::qp)."""
    w = y @ u
    worst = 0.0
    for i in range(len(u)):
        grad = 2.0 * w[i]
        lo, hi = s[i] - lam, s[i] + lam
        tol = 1e-9 * (1.0 + abs(lam) + abs(s[i]))
        if u[i] <= lo + tol:
            v = max(-grad, 0.0)
        elif u[i] >= hi - tol:
            v = max(grad, 0.0)
        else:
            v = abs(grad)
        worst = max(worst, v, max(lo - u[i], 0.0), max(u[i] - hi, 0.0))
    return worst


@given(
    n=st.integers(1, 12),
    seed=st.integers(0, 10_000),
    lam=st.floats(0.05, 2.0),
    nsweeps=st.sampled_from([1, 4, 8]),
)
def test_kernel_matches_ref(n, seed, lam, nsweeps):
    rng = np.random.default_rng(seed)
    y = ref.random_psd(rng, n)
    s = rng.standard_normal(n)
    r = np.full(n, lam)
    # randomly pin some coordinates (masked formulation)
    pins = rng.random(n) < 0.25
    r[pins] = 0.0
    u_ref, w_ref = ref.boxqp_ref(y, s, r, nsweeps)
    u, w = boxqp(y, s, r, nsweeps=nsweeps)
    np.testing.assert_allclose(np.asarray(u), u_ref, atol=1e-11, rtol=1e-9)
    np.testing.assert_allclose(np.asarray(w), w_ref, atol=1e-9, rtol=1e-7)


@given(n=st.integers(1, 10), seed=st.integers(0, 10_000))
@settings(max_examples=15)
def test_kernel_reaches_kkt_optimum(n, seed):
    rng = np.random.default_rng(seed)
    y = ref.random_psd(rng, n, ridge=0.2)
    s = rng.standard_normal(n)
    lam = 0.5
    u, _ = boxqp(y, s, np.full(n, lam), nsweeps=200)
    res = kkt_residual(y, s, lam, np.asarray(u))
    assert res < 1e-6 * (1.0 + np.trace(y)), f"KKT residual {res}"


def test_pinned_coordinates_stay_at_center():
    rng = np.random.default_rng(3)
    n = 8
    y = ref.random_psd(rng, n)
    s = rng.standard_normal(n)
    r = np.full(n, 0.7)
    r[2] = 0.0
    r[5] = 0.0
    u, _ = boxqp(y, s, r, nsweeps=16)
    u = np.asarray(u)
    assert u[2] == s[2] and u[5] == s[5]
    assert np.all(np.abs(u - s) <= 0.7 + 1e-12)


def test_zero_matrix_edge_case():
    # Y = 0: objective constant 0; coordinate rule picks a box edge.
    n = 4
    y = np.zeros((n, n))
    s = np.array([1.0, -1.0, 0.0, 2.0])
    u, w = boxqp(y, s, np.full(n, 0.5), nsweeps=2)
    u = np.asarray(u)
    assert np.all(np.abs(u - s) <= 0.5 + 1e-12)
    np.testing.assert_allclose(np.asarray(w), 0.0)


def test_objective_decreases_with_more_sweeps():
    rng = np.random.default_rng(4)
    n = 10
    y = ref.random_psd(rng, n, ridge=0.01)
    s = rng.standard_normal(n)
    r = np.full(n, 1.0)
    prev = np.inf
    for nsweeps in [1, 2, 4, 16]:
        u, w = boxqp(y, s, r, nsweeps=nsweeps)
        obj = float(np.asarray(u) @ np.asarray(w))
        assert obj <= prev + 1e-10
        prev = obj


def test_f32_inputs_upcast():
    rng = np.random.default_rng(5)
    n = 6
    y = ref.random_psd(rng, n).astype(np.float32)
    s = rng.standard_normal(n).astype(np.float32)
    u, _ = boxqp(y, s, np.full(n, 0.5, dtype=np.float32), nsweeps=4)
    assert np.asarray(u).dtype == np.float64
