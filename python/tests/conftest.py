"""Shared pytest fixtures/settings for the kernel test suite."""

import os
import sys

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

jax.config.update("jax_enable_x64", True)

from hypothesis import settings

# 1-core container: keep the per-case budget modest but deterministic.
settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
settings.load_profile("ci")
