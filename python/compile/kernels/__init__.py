"""L1 Pallas kernels: the paper's compute hot-spots.

- `boxqp` — the box-constrained QP coordinate descent (paper Eq. 11–13),
  the inner loop of Algorithm 1.
- `gram` — blocked AᵀA accumulation for covariance assembly.
- `ref` — pure-numpy oracles both kernels are verified against.

Kernels are lowered with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute. The BlockSpec structure is
still written TPU-shaped (see DESIGN.md §Hardware-Adaptation).
"""
