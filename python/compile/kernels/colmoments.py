"""L1 Pallas kernel: per-column sums and sums-of-squares over a dense row
block — the moment-pass building block (Σ_ii = E[x²] − E[x]² needs exactly
these two reductions per feature).

The streaming pipeline computes moments natively from sparse triples (far
cheaper for bag-of-words sparsity); this kernel is the dense-block
counterpart used when the corpus arrives as dense shards, and it completes
the L1 coverage of every pipeline stage.

TPU mapping: grid over column tiles; each program reduces an (M × TILE)
VMEM-resident slab along rows with VPU adds — a bandwidth-bound kernel
whose arithmetic intensity (2 flops / 8 bytes) puts it squarely at the HBM
roofline; tiling exists purely to bound VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

TILE = 128


def _colmoments_kernel(a_ref, s_ref, ss_ref):
    a = a_ref[...]
    s_ref[...] = jnp.sum(a, axis=0)
    ss_ref[...] = jnp.sum(a * a, axis=0)


@jax.jit
def col_moments(a: jax.Array):
    """Per-column (sum, sum of squares) of an (m, n) block; n % TILE == 0."""
    m, n = a.shape
    assert n % TILE == 0, f"n={n} not {TILE}-aligned"
    a = a.astype(jnp.float64)
    grid = (n // TILE,)
    return pl.pallas_call(
        _colmoments_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, TILE), lambda j: (0, j))],
        out_specs=(
            pl.BlockSpec((TILE,), lambda j: (j,)),
            pl.BlockSpec((TILE,), lambda j: (j,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float64),
            jax.ShapeDtypeStruct((n,), jnp.float64),
        ),
        interpret=True,
    )(a)
