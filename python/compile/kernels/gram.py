"""L1 Pallas kernel: blocked Gram matrix AᵀA.

Covariance assembly building block: the reduced covariance is
Σ̂ = AᵀA/m − μμᵀ over the kept features, and this kernel produces the AᵀA
term for one dense row-block of A; the Rust side accumulates across blocks
and applies the centering.

TPU mapping: classic three-dimensional matmul grid. The output is tiled
(TILE × TILE); the contraction dimension is the innermost grid axis so each
output tile accumulates in VMEM across k-steps; every step is one
TILE×TILE·TILE×TILE matmul — exactly the MXU's shape. On a real TPU this
would run bf16/f32 on the systolic array; here it is f64 + interpret=True
to match the solver's precision on the CPU PJRT backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

TILE = 128


def _gram_kernel(ai_ref, aj_ref, o_ref):
    """Accumulate one (i, j) output tile over the k-th row block."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += ai_ref[...].T @ aj_ref[...]


@jax.jit
def gram(a: jax.Array) -> jax.Array:
    """AᵀA of an (m, n) block; m and n must be multiples of TILE."""
    m, n = a.shape
    assert m % TILE == 0 and n % TILE == 0, f"block shape {a.shape} not {TILE}-aligned"
    a = a.astype(jnp.float64)
    grid = (n // TILE, n // TILE, m // TILE)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, k: (k, i)),
            pl.BlockSpec((TILE, TILE), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float64),
        interpret=True,
    )(a, a)


@functools.partial(jax.jit, static_argnames=())
def gram_normalized(a: jax.Array) -> jax.Array:
    """AᵀA / m — covariance convention used by the pipeline."""
    return gram(a) / a.shape[0]
