"""L1 Pallas kernel: box-constrained QP coordinate descent (Eq. 11–13).

This is the paper's compute hot-spot — the inner solver of every
Algorithm-1 column update:

    R² = min_u uᵀ Y u   s.t.  |uᵢ − sᵢ| ≤ rᵢ

Generalized per-coordinate radii support the masked full-size formulation
(rⱼ = 0 pins uⱼ = sⱼ; with sⱼ = 0 that is exactly "coordinate j removed"),
which is what keeps every shape static for AOT.

TPU mapping (DESIGN.md §Hardware-Adaptation): the whole Y tile stays
resident in VMEM (n ≤ 512 ⇒ ≤ 2 MiB f64 — fits), and the sequential
coordinate recurrence streams over it; each step is one row-dot + one
row-axpy, both of which vectorize across the 8×128 VPU lanes. The kernel
is latency-bound, not MXU-bound — the paper's algorithm is inherently a
sequential coordinate method, and the win is keeping Y on-chip across all
`nsweeps × n` steps instead of re-reading HBM.

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls);
correctness is pinned to `ref.boxqp_ref` by `python/tests/test_boxqp.py`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _coordinate_step(i, carry, y, s, r):
    """One Eq.-(13) update of coordinate i, maintaining w = Y u."""
    u, w = carry
    n = y.shape[0]
    yi = jax.lax.dynamic_slice(y, (i, 0), (1, n))[0]  # row i of Y
    yii = jax.lax.dynamic_index_in_dim(yi, i, keepdims=False)
    ui = jax.lax.dynamic_index_in_dim(u, i, keepdims=False)
    si = jax.lax.dynamic_index_in_dim(s, i, keepdims=False)
    ri = jax.lax.dynamic_index_in_dim(r, i, keepdims=False)
    wi = jax.lax.dynamic_index_in_dim(w, i, keepdims=False)
    g = wi - yii * ui
    lo, hi = si - ri, si + ri
    # y1 > 0: clipped unconstrained minimizer; y1 == 0: box edge by sign(g).
    unc = jnp.where(yii > 0.0, -g / jnp.where(yii > 0.0, yii, 1.0), 0.0)
    interior = jnp.clip(unc, lo, hi)
    edge = jnp.where(g > 0.0, lo, hi)
    new = jnp.where(ri == 0.0, si, jnp.where(yii > 0.0, interior, edge))
    delta = new - ui
    w = w + delta * yi
    u = jax.lax.dynamic_update_index_in_dim(u, new, i, 0)
    return u, w


def _boxqp_kernel(y_ref, s_ref, r_ref, u_ref, w_ref, *, nsweeps: int):
    """Pallas kernel body: whole problem resident in one VMEM tile."""
    y = y_ref[...]
    s = s_ref[...]
    r = r_ref[...]
    n = y.shape[0]
    u0 = s  # box center: always feasible
    w0 = y @ u0

    def sweep(_, carry):
        return jax.lax.fori_loop(
            0, n, lambda i, c: _coordinate_step(i, c, y, s, r), carry
        )

    u, w = jax.lax.fori_loop(0, nsweeps, sweep, (u0, w0))
    u_ref[...] = u
    w_ref[...] = w


@functools.partial(jax.jit, static_argnames=("nsweeps",))
def boxqp(y: jax.Array, s: jax.Array, r: jax.Array, *, nsweeps: int = 8):
    """Solve the box QP; returns (u, w) with w = Y u.

    R² is then `u @ w` — left to the caller (the L2 sweep) so the kernel
    output stays a plain pair of vectors.
    """
    n = y.shape[0]
    assert y.shape == (n, n) and s.shape == (n,) and r.shape == (n,)
    dtype = jnp.float64
    return pl.pallas_call(
        functools.partial(_boxqp_kernel, nsweeps=nsweeps),
        out_shape=(
            jax.ShapeDtypeStruct((n,), dtype),
            jax.ShapeDtypeStruct((n,), dtype),
        ),
        interpret=True,  # CPU PJRT target; see module docstring
    )(y.astype(dtype), s.astype(dtype), r.astype(dtype))
