"""Pure-numpy oracles for the Pallas kernels and the L2 sweep.

These are deliberately independent implementations (plain Python loops, no
jax) — the CORE correctness signal for the kernel layer. The Rust native
engine implements the same algorithms a third time; the three-way agreement
is checked across the test suites.
"""

from __future__ import annotations

import numpy as np


def coordinate_update(y1: float, g: float, s1: float, r: float) -> float:
    """Closed-form scalar update, paper Eq. (13), with box radius r."""
    lo, hi = s1 - r, s1 + r
    if y1 > 0.0:
        unc = -g / y1
        return min(max(unc, lo), hi)
    # y1 == 0 (PSD ⇒ y1 ≥ 0): linear objective, pick a box edge.
    return lo if g > 0.0 else hi


def boxqp_ref(y: np.ndarray, s: np.ndarray, r: np.ndarray, nsweeps: int):
    """Cyclic coordinate descent for min uᵀYu s.t. |uᵢ − sᵢ| ≤ rᵢ.

    Starts at the box center u = s (coordinates with r = 0 stay pinned).
    Returns (u, w) with w = Y u, matching the kernel's outputs.
    """
    y = np.asarray(y, dtype=np.float64)
    s = np.asarray(s, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    n = y.shape[0]
    u = s.copy()
    w = y @ u
    for _ in range(nsweeps):
        for i in range(n):
            if r[i] == 0.0:
                new = s[i]
            else:
                g = w[i] - y[i, i] * u[i]
                new = coordinate_update(y[i, i], g, s[i], r[i])
            delta = new - u[i]
            if delta != 0.0:
                w += delta * y[i]
                u[i] = new
    return u, w


def solve_tau_ref(r2: float, beta: float, c: float, iters: int = 200) -> float:
    """Bisection for the unique positive root of τ³ + cτ² − βτ − R² = 0."""

    def g(tau):
        return tau + c - beta / tau - r2 / (tau * tau)

    hi = max(1.0, 1.0 + beta + r2 - c)
    while g(hi) < 0.0:
        hi *= 2.0
    lo = min(1e-12, hi * 0.5)
    while lo > 1e-300 and g(lo) > 0.0:
        lo *= 0.5
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if g(mid) < 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def bca_sweep_ref(
    x: np.ndarray,
    sigma: np.ndarray,
    lam: float,
    beta: float,
    qp_sweeps: int,
) -> np.ndarray:
    """One full Algorithm-1 sweep (paper steps 3–7), masked formulation.

    Mirrors exactly what the L2 jax graph does so the two can be compared
    elementwise: fixed qp_sweeps, bisection τ, column write-back w/τ.
    """
    x = np.array(x, dtype=np.float64, copy=True)
    sigma = np.asarray(sigma, dtype=np.float64)
    n = x.shape[0]
    for j in range(n):
        mask = np.zeros(n, dtype=bool)
        mask[j] = True
        y = x.copy()
        y[j, :] = 0.0
        y[:, j] = 0.0
        s = sigma[j].copy()
        s[j] = 0.0
        r = np.full(n, lam)
        r[j] = 0.0
        u, w = boxqp_ref(y, s, r, qp_sweeps)
        r2 = max(float(u @ w), 0.0)
        t = np.trace(x) - x[j, j]
        c = sigma[j, j] - lam - t
        tau = solve_tau_ref(r2, beta, c)
        newcol = w / tau
        newcol[j] = c + tau
        x[j, :] = newcol
        x[:, j] = newcol
    return x


def barrier_objective_ref(x, sigma, lam, beta):
    """Objective of problem (6); -inf if x is not PD."""
    sign, logdet = np.linalg.slogdet(x)
    if sign <= 0:
        return -np.inf
    tr = np.trace(x)
    return float(np.sum(sigma * x) - lam * np.abs(x).sum() - 0.5 * tr * tr + beta * logdet)


def power_iter_ref(sigma: np.ndarray, v0: np.ndarray, iters: int):
    """Fixed-iteration power method; returns (v, rayleigh)."""
    v = np.asarray(v0, dtype=np.float64).copy()
    nrm = np.linalg.norm(v)
    if nrm > 0:
        v /= nrm
    for _ in range(iters):
        av = sigma @ v
        nrm = np.linalg.norm(av)
        if nrm > 1e-300:
            v = av / nrm
    return v, float(v @ (sigma @ v))


def gram_ref(a: np.ndarray) -> np.ndarray:
    """AᵀA (unnormalized; the caller divides by m)."""
    a = np.asarray(a, dtype=np.float64)
    return a.T @ a


def random_psd(rng: np.random.Generator, n: int, ridge: float = 0.05) -> np.ndarray:
    """Random PSD test matrix FᵀF/m + ridge·I."""
    m = n + 3
    f = rng.standard_normal((m, n))
    return f.T @ f / m + ridge * np.eye(n)
