"""Build-time compile package: L2 JAX graphs + L1 Pallas kernels + AOT.

Nothing in here runs at serving/request time — `make artifacts` lowers the
graphs once to HLO text under `artifacts/`, and the Rust coordinator loads
them through PJRT (see rust/src/runtime.rs).

All numerics are float64: the BCA solver's τ / barrier arithmetic needs the
headroom, and the CPU PJRT backend executes f64 natively.
"""

import jax

jax.config.update("jax_enable_x64", True)
