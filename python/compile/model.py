"""L2 JAX graphs: one full Algorithm-1 sweep, power iteration, Gram.

These are the computations the Rust coordinator executes through PJRT.
Everything is shape-static (AOT requirement); the BCA sweep uses the
*masked full-size* formulation so no dynamic-shape minor extraction is
needed (DESIGN.md "Fixed shapes and masking"):

  column j's sub-QP runs over the full n-vector with
    Y := X with row/col j zeroed,   s := Σ_j with s[j] = 0,
    r := λ everywhere except r[j] = 0  (pins u[j] = 0),
  which reproduces the (n−1)-minor problem exactly.

Constants QP_SWEEPS / POWER_ITERS are mirrored in rust/src/engine.rs
(XLA_QP_SWEEPS / XLA_POWER_ITERS) — the agreement tests rely on both sides
using the same inner-iteration budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.boxqp import boxqp
from compile.kernels.colmoments import col_moments
from compile.kernels.gram import gram

jax.config.update("jax_enable_x64", True)

QP_SWEEPS = 8
POWER_ITERS = 100
TAU_BISECT_ITERS = 128


def solve_tau(r2: jax.Array, beta: jax.Array, c: jax.Array) -> jax.Array:
    """Unique positive root of τ³ + cτ² − βτ − R² = 0 by fixed bisection.

    The bracket [lo, hi] provably contains the root: the derivative
    g(τ) = τ + c − β/τ − R²/τ² is increasing, g(lo) < 0 for tiny lo and
    g(hi) ≥ hi + c − β − R² ≥ 1 > 0 for hi = max(1, 1 + β + R² − c).
    """

    def g(tau):
        return tau + c - beta / tau - r2 / (tau * tau)

    hi0 = jnp.maximum(1.0, 1.0 + beta + r2 - c)
    lo0 = jnp.float64(1e-30)

    def body(_, bracket):
        lo, hi = bracket
        mid = 0.5 * (lo + hi)
        neg = g(mid) < 0.0
        return jnp.where(neg, mid, lo), jnp.where(neg, hi, mid)

    lo, hi = jax.lax.fori_loop(0, TAU_BISECT_ITERS, body, (lo0, hi0))
    return 0.5 * (lo + hi)


def bca_column_update(x, sigma, lam, beta, j):
    """Steps 4–6 of Algorithm 1 for column j (masked formulation)."""
    n = x.shape[0]
    mask = jnp.arange(n) == j
    y = jnp.where(mask[:, None] | mask[None, :], 0.0, x)
    s = jnp.where(mask, 0.0, jax.lax.dynamic_slice(sigma, (j, 0), (1, n))[0])
    r = jnp.where(mask, 0.0, lam)
    u, w = boxqp(y, s, r, nsweeps=QP_SWEEPS)  # L1 Pallas kernel
    r2 = jnp.maximum(u @ w, 0.0)
    xjj = jax.lax.dynamic_index_in_dim(jnp.diagonal(x), j, keepdims=False)
    t = jnp.trace(x) - xjj
    sjj = jax.lax.dynamic_index_in_dim(jnp.diagonal(sigma), j, keepdims=False)
    c = sjj - lam - t
    tau = solve_tau(r2, beta, c)
    newcol = jnp.where(mask, c + tau, w / tau)
    x = x.at[j, :].set(newcol)
    x = x.at[:, j].set(newcol)
    return x


@jax.jit
def bca_sweep(x, sigma, lam, beta):
    """One full sweep over all n columns; returns the updated X."""
    n = x.shape[0]
    x = jax.lax.fori_loop(
        0, n, lambda j, xx: bca_column_update(xx, sigma, lam, beta, j), x
    )
    return (x,)


@jax.jit
def power_iter(sigma, v0):
    """POWER_ITERS rounds of power iteration; returns (v, rayleigh)."""

    def body(_, v):
        av = sigma @ v
        nrm = jnp.linalg.norm(av)
        return jnp.where(nrm > 1e-300, av / nrm, v)

    v = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-300)
    v = jax.lax.fori_loop(0, POWER_ITERS, body, v)
    value = v @ (sigma @ v)
    return v, value


@jax.jit
def gram_block(a):
    """AᵀA of a dense row block (L1 Pallas gram kernel)."""
    return (gram(a),)


@jax.jit
def col_moments_block(a):
    """Per-column (sum, sum²) of a dense row block (L1 Pallas kernel)."""
    return col_moments(a)


# ---------------------------------------------------------------------------
# numpy-facing helpers used by the python test-suite
# ---------------------------------------------------------------------------


def bca_sweep_np(x, sigma, lam, beta):
    """Run the jitted sweep on numpy inputs, return numpy."""
    import numpy as np

    (out,) = bca_sweep(
        jnp.asarray(x, jnp.float64),
        jnp.asarray(sigma, jnp.float64),
        jnp.float64(lam),
        jnp.float64(beta),
    )
    return np.asarray(out)


@functools.lru_cache(maxsize=None)
def _compiled_shapes():  # pragma: no cover - debugging helper
    return {}
