"""AOT lowering: JAX graphs → HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Emitted artifacts (sizes mirrored in rust/src/engine.rs::XLA_SIZES):

    bca_sweep_n{N}.hlo.txt    (X, Σ, λ, β)  → (X′,)
    power_iter_n{N}.hlo.txt   (Σ, v0)       → (v, value)
    gram_b{M}x{K}.hlo.txt     (A,)          → (AᵀA,)

Usage: python -m compile.aot --out-dir ../artifacts [--sizes 32,64,...]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

jax.config.update("jax_enable_x64", True)

SIZES = [32, 64, 128, 256, 512]
GRAM_BLOCK = (256, 512)
MOMENTS_BLOCK = (1024, 512)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bca_sweep(n: int) -> str:
    mat = jax.ShapeDtypeStruct((n, n), jnp.float64)
    scalar = jax.ShapeDtypeStruct((), jnp.float64)
    return to_hlo_text(jax.jit(model.bca_sweep).lower(mat, mat, scalar, scalar))


def lower_power_iter(n: int) -> str:
    mat = jax.ShapeDtypeStruct((n, n), jnp.float64)
    vec = jax.ShapeDtypeStruct((n,), jnp.float64)
    return to_hlo_text(jax.jit(model.power_iter).lower(mat, vec))


def lower_gram(m: int, k: int) -> str:
    blk = jax.ShapeDtypeStruct((m, k), jnp.float64)
    return to_hlo_text(jax.jit(model.gram_block).lower(blk))


def lower_col_moments(m: int, k: int) -> str:
    blk = jax.ShapeDtypeStruct((m, k), jnp.float64)
    return to_hlo_text(jax.jit(model.col_moments_block).lower(blk))


def emit(out_dir: str, sizes: list[int], gram_block=GRAM_BLOCK, verbose=True) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []

    def write(name: str, text: str):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        if verbose:
            print(f"  {name}: {len(text) / 1024:.0f} KiB")

    for n in sizes:
        if verbose:
            print(f"lowering n={n} ...", flush=True)
        write(f"bca_sweep_n{n}", lower_bca_sweep(n))
        write(f"power_iter_n{n}", lower_power_iter(n))
    m, k = gram_block
    if verbose:
        print(f"lowering gram {m}x{k} ...", flush=True)
    write(f"gram_b{m}x{k}", lower_gram(m, k))
    mm, mk = MOMENTS_BLOCK
    if verbose:
        print(f"lowering col_moments {mm}x{mk} ...", flush=True)
    write(f"col_moments_b{mm}x{mk}", lower_col_moments(mm, mk))
    return written


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in SIZES),
        help="comma-separated BCA/power artifact sizes",
    )
    args = ap.parse_args(argv)
    sizes = [int(s) for s in args.sizes.split(",") if s]
    written = emit(args.out_dir, sizes)
    print(f"wrote {len(written)} artifacts to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
