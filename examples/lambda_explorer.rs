//! λ-exploration: the sparsity/variance trade-off path of DSPCA on one
//! covariance — cardinality, explained variance, objective and reduced
//! problem size as λ sweeps from dense to fully sparse. Shows the
//! mechanics behind §4's "coarse range of λ" search.
//!
//! ```bash
//! cargo run --release --example lambda_explorer             # spiked n=80
//! cargo run --release --example lambda_explorer -- 120 40
//! ```

use lsspca::corpus::models::spiked_covariance_with_u;
use lsspca::elim::SafeElimination;
use lsspca::solver::bca::{self, BcaOptions};
use lsspca::solver::extract::leading_sparse_pc;
use lsspca::solver::threshold::thresholded_pc;
use lsspca::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(80);
    let m: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2 * n);
    let card = (n / 10).max(3);
    let mut rng = Rng::seed_from(42);
    let (sigma, truth) = spiked_covariance_with_u(n, m, card, 6.0, &mut rng);
    let truth_support = lsspca::linalg::vec::support(&truth, 1e-9);
    let diags: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
    let max_diag = diags.iter().cloned().fold(0.0f64, f64::max);

    println!("# λ path on spiked covariance (n={n}, planted card={card})");
    println!(
        "{:>10} {:>6} {:>6} {:>10} {:>10} {:>8} {:>8}",
        "lambda", "n̂", "card", "phi", "expl.var", "recall", "time(s)"
    );
    let steps = 14;
    for k in 0..steps {
        let lambda = max_diag * (k as f64 + 0.5) / steps as f64;
        // Safe elimination first (Thm 2.1), then solve the reduced problem.
        let elim = SafeElimination::apply(&diags, lambda, None);
        if elim.reduced() == 0 {
            println!("{lambda:>10.4} {:>6} — every feature eliminated", 0);
            continue;
        }
        let reduced = sigma.submatrix(&elim.kept);
        let sol = bca::solve(&reduced, lambda, &BcaOptions { max_sweeps: 10, ..Default::default() });
        let pc = leading_sparse_pc(&sol.z, 1e-3);
        let full = elim.lift(&pc.vector);
        let support = lsspca::linalg::vec::support(&full, 1e-9);
        let recall = support.iter().filter(|i| truth_support.contains(i)).count() as f64
            / truth_support.len() as f64;
        let expl = {
            let mut w = vec![0.0; n];
            sigma.matvec(&full, &mut w);
            lsspca::linalg::vec::dot(&full, &w)
        };
        println!(
            "{lambda:>10.4} {:>6} {:>6} {:>10.4} {:>10.4} {:>8.2} {:>8.3}",
            elim.reduced(),
            support.len(),
            sol.phi,
            expl,
            recall,
            sol.seconds
        );
    }

    // Baseline comparison at the planted cardinality.
    let thr = thresholded_pc(&sigma, card);
    let thr_recall = thr
        .support
        .iter()
        .filter(|i| truth_support.contains(i))
        .count() as f64
        / truth_support.len() as f64;
    println!(
        "\nsimple thresholding at k={card}: explained={:.4} recall={:.2} (ad-hoc baseline [4])",
        thr.explained_variance(&sigma),
        thr_recall
    );
}
