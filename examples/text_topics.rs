//! End-to-end driver (DESIGN.md E3/E5): generate an NYTimes-like corpus,
//! stream it through the full pipeline — sharded variance pass, safe
//! feature elimination, reduced covariance pass, λ-search + BCA per
//! component with deflation — and print the paper-style topic table plus
//! the headline metrics (reduction factor, per-PC wall time).
//!
//! ```bash
//! cargo run --release --example text_topics                 # default scale
//! cargo run --release --example text_topics -- 100000 50000 # docs vocab
//! cargo run --release --example text_topics -- 50000 30000 xla  # AOT engine
//! ```
//!
//! The run is recorded in EXPERIMENTS.md (E3 headline run).

use lsspca::config::PipelineConfig;
use lsspca::coordinator::Pipeline;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let docs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let vocab: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30_000);
    let engine = args.get(2).cloned().unwrap_or_else(|| "native".into());

    let cfg = PipelineConfig {
        synth_preset: "nytimes".into(),
        synth_docs: docs,
        synth_vocab: vocab,
        num_pcs: 5,
        target_card: 5,
        card_slack: 2,
        max_reduced: 512,
        workers: 2,
        engine,
        ..Default::default()
    };
    cfg.validate().expect("config");
    println!(
        "# text_topics — NYTimes-like corpus, {docs} docs × {vocab} words, engine={}",
        cfg.engine
    );
    let report = Pipeline::new(cfg).run().expect("pipeline");

    println!(
        "\ncorpus: {} docs, {} features, {} nnz",
        report.num_docs, report.vocab_size, report.nnz
    );
    println!(
        "safe elimination: n={} → n̂={}  (reduction ×{:.0}, λ̂={:.4e}{})",
        report.vocab_size,
        report.reduced_size,
        report.reduction_factor,
        report.elim_lambda,
        if report.elim_capped { ", capped" } else { "" }
    );
    println!("\n## Top 5 sparse principal components (cf. paper Table 1)\n");
    println!("{}", report.topic_table);
    println!("## Per-component metrics\n");
    for (k, c) in report.components.iter().enumerate() {
        println!(
            "PC{}: cardinality={} λ={:.4} φ={:.4} explained_variance={:.4} wall={:.2}s",
            k + 1,
            c.pc.cardinality(),
            c.lambda,
            c.phi,
            c.explained_variance,
            c.seconds
        );
    }
    let per_pc: f64 =
        report.components.iter().map(|c| c.seconds).sum::<f64>() / report.components.len() as f64;
    println!(
        "\nheadline: reduction ×{:.0} (paper: 150–200×); mean per-PC solve {:.2}s \
         (paper: ~20 s on a 2011 laptop at full NYTimes scale)",
        report.reduction_factor, per_pc
    );
    println!(
        "total pipeline: {:.2}s\n\nprofile:\n{}",
        report.total_seconds, report.profile
    );
}
