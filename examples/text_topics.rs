//! End-to-end driver (DESIGN.md E3/E5): generate an NYTimes-like corpus,
//! stream it through the full pipeline — sharded variance pass, safe
//! feature elimination, reduced covariance pass, λ-search + BCA per
//! component with deflation — and print the paper-style topic table plus
//! the headline metrics (reduction factor, per-PC wall time).
//!
//! Written against the staged session API: the stages run explicitly
//! (`stream → eliminate → reduce → fit`) so the example doubles as the
//! migration reference from the old one-shot `Pipeline::run`.
//!
//! ```bash
//! cargo run --release --example text_topics                 # default scale
//! cargo run --release --example text_topics -- 100000 50000 # docs vocab
//! cargo run --release --example text_topics -- 50000 30000 xla  # AOT engine
//! ```
//!
//! The run is recorded in EXPERIMENTS.md (E3 headline run).

use lsspca::session::{LambdaSpec, Session};
use lsspca::util::Timer;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let docs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let vocab: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30_000);
    let engine = args.get(2).cloned().unwrap_or_else(|| "native".into());

    let mut session = Session::builder()
        .synthetic("nytimes")
        .synth_size(docs, vocab)
        .num_pcs(5)
        .target_card(5)
        .card_slack(2)
        .max_reduced(512)
        .workers(2)
        .engine(&engine)
        .build()
        .expect("config");
    println!(
        "# text_topics — NYTimes-like corpus, {docs} docs × {vocab} words, engine={}",
        session.config().engine
    );

    // Stage by stage (each caches; a second fit would reuse all three):
    let total = Timer::start();
    let (num_docs, vocab_size, nnz) = {
        let stats = session.stream().expect("variance pass");
        (stats.docs, stats.vocab_size(), stats.nnz)
    };
    let (reduced_size, reduction_factor, elim_lambda, elim_capped) = {
        let plan = session.eliminate(5).expect("elimination");
        (
            plan.elim.reduced(),
            plan.elim.reduction_factor(),
            plan.elim.lambda,
            plan.capped,
        )
    };
    session.reduce().expect("covariance pass");
    let fit = session.fit(LambdaSpec::search(5, 2), 5).expect("fit");
    let total_seconds = total.secs();

    println!("\ncorpus: {num_docs} docs, {vocab_size} features, {nnz} nnz");
    println!(
        "safe elimination: n={vocab_size} → n̂={reduced_size}  (reduction ×{:.0}, λ̂={:.4e}{})",
        reduction_factor,
        elim_lambda,
        if elim_capped { ", capped" } else { "" }
    );
    println!("\n## Top 5 sparse principal components (cf. paper Table 1)\n");
    println!("{}", fit.topic_table);
    println!("## Per-component metrics\n");
    for (k, c) in fit.components.iter().enumerate() {
        println!(
            "PC{}: cardinality={} λ={:.4} φ={:.4} explained_variance={:.4} wall={:.2}s",
            k + 1,
            c.pc.cardinality(),
            c.lambda,
            c.phi,
            c.explained_variance,
            c.seconds
        );
    }
    let per_pc: f64 =
        fit.components.iter().map(|c| c.seconds).sum::<f64>() / fit.components.len() as f64;
    println!(
        "\nheadline: reduction ×{:.0} (paper: 150–200×); mean per-PC solve {:.2}s \
         (paper: ~20 s on a 2011 laptop at full NYTimes scale)",
        reduction_factor, per_pc
    );
    println!(
        "total pipeline: {total_seconds:.2}s\n\nprofile:\n{}",
        session.profile()
    );
}
