//! Quickstart: sparse PCA on a small spiked covariance in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lsspca::corpus::spiked_covariance_with_u;
use lsspca::elim::SafeElimination;
use lsspca::solver::bca::{self, BcaOptions};
use lsspca::solver::extract::leading_sparse_pc;
use lsspca::util::rng::Rng;

fn main() {
    // A 60-feature covariance with a planted 5-sparse spike.
    let mut rng = Rng::seed_from(2011);
    let (sigma, truth) = spiked_covariance_with_u(60, 300, 5, 6.0, &mut rng);

    // Step 1 — safe feature elimination (Thm 2.1): pick λ, drop every
    // feature with Σ_ii < λ *before* solving.
    let diags: Vec<f64> = (0..60).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&diags, 20);
    let elim = SafeElimination::apply(&diags, lambda, None);
    println!(
        "safe elimination at λ={lambda:.3}: {} → {} features",
        elim.original,
        elim.reduced()
    );

    // Step 2 — block coordinate ascent (Algorithm 1) on the reduced problem.
    let reduced = sigma.submatrix(&elim.kept);
    let sol = bca::solve(&reduced, lambda, &BcaOptions::default());
    println!(
        "BCA: φ={:.4} in {} sweeps ({:.1} ms)",
        sol.phi,
        sol.sweeps,
        sol.seconds * 1e3
    );

    // Step 3 — extract the sparse PC and lift it back to full coordinates.
    let pc = leading_sparse_pc(&sol.z, 1e-3);
    let full = elim.lift(&pc.vector);
    let support = lsspca::linalg::vec::support(&full, 1e-9);
    println!("sparse PC support: {support:?}");
    println!(
        "planted spike:     {:?}",
        lsspca::linalg::vec::support(&truth, 1e-9)
    );
    let overlap = support.iter().filter(|i| truth[**i].abs() > 1e-9).count();
    println!("recovered {overlap}/5 spike coordinates");
}
