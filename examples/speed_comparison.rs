//! Fig 1 reproduction (DESIGN.md E1a/E1b): convergence speed of Block
//! Coordinate Ascent vs the first-order DSPCA method, on both of the
//! paper's covariance models.
//!
//! ```bash
//! cargo run --release --example speed_comparison            # n = 100
//! cargo run --release --example speed_comparison -- 200 60  # n, m
//! ```

use lsspca::corpus::models::{gaussian_factor_cov, spiked_covariance_with_u};
use lsspca::data::SymMat;
use lsspca::solver::bca::{self, BcaOptions};
use lsspca::solver::first_order::{self, FirstOrderOptions};
use lsspca::util::plot::AsciiPlot;
use lsspca::util::rng::Rng;

fn run_model(name: &str, sigma: &SymMat, lambda: f64) {
    println!("\n== {name} (n={}, λ={lambda:.3}) ==", sigma.n());
    let b = bca::solve(
        sigma,
        lambda,
        &BcaOptions { max_sweeps: 12, epsilon: 1e-3, tol: 1e-9, ..Default::default() },
    );
    let f = first_order::solve(
        sigma,
        lambda,
        &FirstOrderOptions { max_iters: 4000, epsilon: 5e-2, gap_tol: 1e-4, ..Default::default() },
    );
    println!(
        "BCA        : φ={:.6} after {} sweeps, {:.3}s",
        b.phi, b.sweeps, b.seconds
    );
    println!(
        "first-order: φ={:.6} after {} iters,  {:.3}s (dual bound {:.6})",
        f.phi, f.iters, f.seconds, f.dual_bound
    );
    let bca_pts: Vec<(f64, f64)> = b
        .history
        .iter()
        .map(|h| (h.seconds.max(1e-5), h.objective))
        .collect();
    let fo_pts: Vec<(f64, f64)> = f
        .history
        .iter()
        .map(|&(_, obj, secs)| (secs.max(1e-5), obj))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("objective vs CPU time (log t) — cf. paper Fig 1")
            .logx()
            .series("BCA", 'B', &bca_pts)
            .series("first-order", 'f', &fo_pts)
            .render()
    );
    // Speedup at matched quality: first time each method reaches 99% of
    // the best objective seen by either.
    let target = 0.99 * b.phi.max(f.phi);
    let t_bca = bca_pts.iter().find(|&&(_, o)| o >= target).map(|&(t, _)| t);
    let t_fo = fo_pts.iter().find(|&&(_, o)| o >= target).map(|&(t, _)| t);
    match (t_bca, t_fo) {
        (Some(tb), Some(tf)) => {
            println!("time to 99% of best φ: BCA {tb:.3}s vs first-order {tf:.3}s  (×{:.1})", tf / tb)
        }
        (Some(tb), None) => println!("BCA reached target in {tb:.3}s; first-order never did"),
        _ => println!("(target not reached by BCA within budget)"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let m: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(n / 2);

    // Left panel: Σ = FᵀF, F Gaussian.
    let mut rng = Rng::seed_from(1);
    let sigma = gaussian_factor_cov(n, m, &mut rng);
    let diags: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&diags, 3 * n / 4);
    run_model("Gaussian factor model  Σ = FᵀF/m", &sigma, lambda);

    // Right panel: spiked model Σ = uuᵀ + VVᵀ/m, Card(u) = 0.1 n.
    let card = (n / 10).max(2);
    let (sigma, _) = spiked_covariance_with_u(n, m, card, 1.5, &mut rng);
    let diags: Vec<f64> = (0..n).map(|i| sigma.get(i, i)).collect();
    let lambda = lsspca::elim::lambda_for_survivors(&diags, 3 * n / 4);
    run_model("spiked model  Σ = uuᵀ + VVᵀ/m", &sigma, lambda);
}
