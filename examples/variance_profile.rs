//! Fig 2 reproduction (DESIGN.md E2): ranked per-word variances of the
//! NYTimes-like and PubMed-like corpora, streamed with the sharded moment
//! pass. The rapid decay is what makes safe feature elimination so
//! effective on text data.
//!
//! ```bash
//! cargo run --release --example variance_profile
//! cargo run --release --example variance_profile -- 30000 20000
//! ```

use lsspca::corpus::{CorpusSpec, SynthCorpus};
use lsspca::elim::lambda_survivor_curve;
use lsspca::stream::{variance_pass, StreamOptions, SynthSource};
use lsspca::util::plot::AsciiPlot;

fn profile(preset: &str, docs: usize, vocab: usize) {
    let spec = CorpusSpec::preset(preset).unwrap().scaled(docs, vocab);
    let corpus = SynthCorpus::new(spec, 20111212);
    let opts = StreamOptions { workers: 2, chunk_docs: 2048, queue_depth: 4 };
    let (fv, stats) = variance_pass(&mut SynthSource::new(&corpus), opts).unwrap();
    let sorted = fv.sorted_variances();
    println!(
        "\n== {preset}: {} docs × {} words, {} nnz (pass: {:.2}s, {} workers) ==",
        stats.docs, vocab, stats.nnz, stats.seconds, opts.workers
    );
    let pts: Vec<(f64, f64)> = sorted
        .iter()
        .enumerate()
        .filter(|&(_, &v)| v > 0.0)
        .step_by((sorted.len() / 2000).max(1))
        .map(|(i, &v)| ((i + 1) as f64, v))
        .collect();
    println!(
        "{}",
        AsciiPlot::new("sorted word variances (log-log) — cf. paper Fig 2")
            .logx()
            .logy()
            .series("variance", '*', &pts)
            .render()
    );
    // decay summary + λ → n̂ curve (the safe-elimination payoff)
    let decades = (sorted[0] / sorted[sorted.len() / 2].max(1e-300)).log10();
    println!("decay: top variance {:.3}, median ratio 10^{decades:.1}", sorted[0]);
    let lambdas: Vec<f64> = (0..8).map(|k| sorted[0] * 0.5f64.powi(k + 1)).collect();
    println!("λ → surviving features (safe elimination):");
    for (lam, kept) in lambda_survivor_curve(&fv.variance, &lambdas) {
        println!(
            "  λ={lam:10.4}  n̂={kept:>7}  (×{:.0} reduction)",
            vocab as f64 / kept.max(1) as f64
        );
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let docs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let vocab: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30_000);
    profile("nytimes", docs, vocab);
    profile("pubmed", docs, vocab);
}
